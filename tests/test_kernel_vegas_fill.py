"""Per-kernel allclose sweeps: Pallas vegas_fill (interpret mode) vs the
pure-jnp oracle in kernels/ref.py, across shapes, dtypes and integrands."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as kref
from repro.kernels import vegas_fill as vk

INTEGRANDS = {
    "poly": lambda x: jnp.sum(x * x, axis=-1) + 1.0,
    "oscillatory": lambda x: jnp.sum(jnp.sin(5.0 * x), axis=-1),
    "product_peak": lambda x: jnp.prod(1.0 / (0.1 + (x - 0.3) ** 2), axis=-1),
    "exp": lambda x: jnp.exp(jnp.sum(x, axis=-1)),
}


def _inputs(key, n, d, ninc, nstrat, dtype, lo=-1.0, hi=2.0):
    n_cubes = nstrat**d
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.uniform(k1, (n, d), dtype=dtype)
    cube = jax.random.randint(k2, (n, 1), 0, n_cubes + 1, dtype=jnp.int32)
    w = jax.random.uniform(k3, (d, ninc), minval=0.05, maxval=1.0).astype(dtype)
    w = w / w.sum(1, keepdims=True) * (hi - lo)
    edges_lo = jnp.concatenate(
        [jnp.full((d, 1), lo, dtype), lo + jnp.cumsum(w, 1)[:, :-1]], axis=1)
    return u, cube, edges_lo, w, n_cubes


@pytest.mark.parametrize("n,d,ninc,nstrat,tile", [
    (256, 1, 16, 13, 128),
    (512, 2, 64, 7, 256),
    (512, 4, 128, 3, 128),
    (256, 8, 256, 2, 256),
    (384, 3, 50, 4, 128),   # ninc not a power of two (paper's vf config = 50)
    (256, 16, 32, 2, 64),   # high-dim
])
@pytest.mark.parametrize("igname", ["poly", "oscillatory"])
def test_kernel_matches_ref_shapes(n, d, ninc, nstrat, tile, igname):
    key = jax.random.PRNGKey(n * 1000 + d)
    u, cube, edges_lo, widths, n_cubes = _inputs(key, n, d, ninc, nstrat, jnp.float32)
    ig = INTEGRANDS[igname]
    w_r, ms_r, mc_r = kref.vegas_fill_ref(
        u, cube, edges_lo, widths, nstrat=nstrat, n_cubes=n_cubes, integrand=ig)
    w_k, ms_k, mc_k = vk.vegas_fill(
        u, cube, edges_lo, widths, nstrat=nstrat, n_cubes=n_cubes, integrand=ig,
        tile=tile, interpret=True)
    # atol scales with the output magnitude: near integrand zeros the last-ulp
    # x difference between gather styles is amplified to ~|w|_max * 1e-5.
    wscale = float(np.abs(np.asarray(w_r)).max()) or 1.0
    msscale = float(np.abs(np.asarray(ms_r)).max()) or 1.0
    np.testing.assert_allclose(w_k, w_r, rtol=1e-5, atol=1e-5 * wscale)
    np.testing.assert_allclose(ms_k, ms_r, rtol=1e-4, atol=1e-5 * msscale)
    np.testing.assert_allclose(mc_k, mc_r, rtol=0, atol=0)


@pytest.mark.parametrize("igname", list(INTEGRANDS))
def test_kernel_matches_ref_integrands(igname):
    key = jax.random.PRNGKey(99)
    u, cube, edges_lo, widths, n_cubes = _inputs(key, 512, 4, 64, 3, jnp.float32)
    ig = INTEGRANDS[igname]
    w_r, ms_r, mc_r = kref.vegas_fill_ref(
        u, cube, edges_lo, widths, nstrat=3, n_cubes=n_cubes, integrand=ig)
    w_k, ms_k, mc_k = vk.vegas_fill(
        u, cube, edges_lo, widths, nstrat=3, n_cubes=n_cubes, integrand=ig,
        tile=256, interpret=True)
    wscale = float(np.abs(np.asarray(w_r)).max()) or 1.0
    msscale = float(np.abs(np.asarray(ms_r)).max()) or 1.0
    np.testing.assert_allclose(w_k, w_r, rtol=1e-4, atol=1e-5 * wscale)
    np.testing.assert_allclose(ms_k, ms_r, rtol=1e-3, atol=1e-5 * msscale)


def test_kernel_all_masked():
    """Every eval in the overflow bucket -> all outputs zero."""
    key = jax.random.PRNGKey(5)
    u, _, edges_lo, widths, n_cubes = _inputs(key, 256, 3, 32, 2, jnp.float32)
    cube = jnp.full((256, 1), n_cubes, jnp.int32)
    w_k, ms_k, mc_k = vk.vegas_fill(
        u, cube, edges_lo, widths, nstrat=2, n_cubes=n_cubes,
        integrand=INTEGRANDS["poly"], tile=128, interpret=True)
    assert float(jnp.abs(w_k).max()) == 0.0
    assert float(jnp.abs(ms_k).max()) == 0.0
    assert float(mc_k.max()) == 0.0


def test_kernel_map_counts_conserve_evals():
    """Each live eval lands in exactly one interval per dimension."""
    key = jax.random.PRNGKey(6)
    n, d = 512, 4
    u, cube, edges_lo, widths, n_cubes = _inputs(key, n, d, 64, 3, jnp.float32)
    _, _, mc = vk.vegas_fill(
        u, cube, edges_lo, widths, nstrat=3, n_cubes=n_cubes,
        integrand=INTEGRANDS["poly"], tile=128, interpret=True)
    live = int((cube < n_cubes).sum())
    np.testing.assert_allclose(np.asarray(mc).sum(axis=1), live, rtol=1e-6)


def test_ops_fill_matches_reference_backend_accumulators():
    """ops.fill (kernel path) and core.fill_reference agree on the cube
    reduction contract given identical uniforms (checked statistically via a
    deterministic integrand of x only)."""
    from repro.kernels import ops as kops
    from repro.core import map as vmap_

    ig = INTEGRANDS["poly"]
    d, ninc, nstrat = 3, 32, 3
    n_cubes = nstrat**d
    edges = vmap_.uniform_edges([0.0] * d, [1.0] * d, ninc)
    n_h = jnp.full((n_cubes,), 4, jnp.int32)
    key = jax.random.PRNGKey(0)
    res = kops.fill(edges, n_h, key, ig, nstrat=nstrat, n_cap=256, chunk=256,
                    interpret=True, tile=128)
    # invariants rather than bit-match (RNG streams differ by design):
    assert res.cube_s1.shape == (n_cubes,)
    assert float(res.map_counts.sum()) == pytest.approx(d * int(n_h.sum()), rel=1e-6)
    assert (np.asarray(res.cube_s2) >= 0).all()
