"""Plan autotuner (ISSUE 8, DESIGN.md §13): cost-model fitting, table
persistence/resolution, the knob chooser through ``make_plan(autotune=True)``,
the serving layer's shared cost model, and the --gate-run pairing logic.

The calibration RUNNER (steady-state timing over the measurement grid) is
exercised ref-only here to keep the suite fast; the full grid is CI's
autotune-smoke job (benchmarks/bench_calibrate.py)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.batch.family import make_gaussian_family
from repro.core import VegasConfig
from repro.core.integrands import make_cosine, make_roos_arnold
from repro.engine import ExecutionConfig, PlanError, available, make_plan
from repro.engine import autotune as at


# --- fitting -----------------------------------------------------------------

def test_nnls_nonnegative():
    # A design whose plain OLS solution has a negative coefficient: the
    # active-set loop must drop it instead of returning it (monotone
    # predictions are the chooser's correctness condition).
    rng = np.random.default_rng(0)
    x = np.column_stack([np.ones(40), rng.uniform(1, 2, 40),
                         rng.uniform(1, 2, 40)])
    y = 2.0 + 3.0 * x[:, 1] - 0.5 * x[:, 2]      # truth has a negative term
    coef = at._nnls(x, y)
    assert (coef >= 0.0).all()
    assert coef[1] > 0.0


def test_fit_class_recovers_planted_coefficients():
    truth = at.ClassCoeffs(c_fixed=1e-3, c_eval_dim=2e-7, c_chunk=5e-4)
    samples = []
    for d in (4, 10):
        for n_cap in (16_384, 65_536, 131_072):
            for n_chunks in (4, 16, 64):
                samples.append(dict(
                    b=1, d=d, n_cap=n_cap, n_chunks=n_chunks, tile=None,
                    seconds=truth.fill_s(b=1, d=d, n_cap=n_cap,
                                         n_chunks=n_chunks)))
    fit = at.fit_class(samples)
    assert fit.c_fixed == pytest.approx(truth.c_fixed, rel=1e-6)
    assert fit.c_eval_dim == pytest.approx(truth.c_eval_dim, rel=1e-6)
    assert fit.c_chunk == pytest.approx(truth.c_chunk, rel=1e-6)
    assert fit.n_samples == len(samples)


def test_calibrate_ref_only_fits_and_saves(tmp_path):
    table = at.calibrate(fast=True, backends=("ref",), repeats=1)
    assert table.source == "calibrated"
    assert table.jax_backend == jax.default_backend()
    c = table.classes[at.class_key("ref")]   # device_kind-qualified
    assert c.n_samples >= 6
    for f in ("c_fixed", "c_eval_dim", "c_chunk", "c_tile_step",
              "iter_overhead_s"):
        assert getattr(c, f) >= 0.0
    # Measured fills take real time: the fit cannot be all-zero.
    assert c.fill_s(b=1, d=10, n_cap=1 << 17, n_chunks=8) > 0.0
    path = table.save(str(tmp_path / "COST_TABLE.json"))
    loaded = at.CostTable.load(path)
    assert loaded.classes[at.class_key("ref")] == c


# --- table persistence + resolution ------------------------------------------

def test_cost_table_roundtrip_and_fallbacks(tmp_path):
    table = at.CostTable(device_kind="cpu", jax_backend="cpu", git_sha="abc",
                         source="calibrated", calibration_wall_s=1.5,
                         classes={"ref": at.ClassCoeffs(c_fixed=0.5),
                                  "pallas|interpret":
                                      at.ClassCoeffs(c_chunk=0.25)})
    path = table.save(str(tmp_path / "t.json"))
    loaded = at.CostTable.load(path)
    assert loaded.source == path            # provenance tracks the file
    assert loaded.classes == dict(table.classes)
    # exact -> sibling mode -> builtin -> ref fallback chain
    assert loaded.coeffs("ref").c_fixed == 0.5
    assert loaded.coeffs("pallas|compiled").c_chunk == 0.25   # sibling
    assert (loaded.coeffs("pallas-fused|interpret")
            == at.BUILTIN_CLASSES["pallas-fused|interpret"])  # builtin
    assert loaded.coeffs("no-such-backend") == at.BUILTIN_CLASSES["ref"]


def test_resolve_table_priority(tmp_path, monkeypatch):
    explicit = at.CostTable(source="calibrated",
                            classes={"ref": at.ClassCoeffs(c_fixed=9.0)})
    assert at.resolve_table(explicit) is explicit
    p = explicit.save(str(tmp_path / "explicit.json"))
    assert at.resolve_table(p).coeffs("ref").c_fixed == 9.0
    with pytest.raises(OSError):
        at.resolve_table(str(tmp_path / "missing.json"))
    envt = at.CostTable(source="calibrated",
                        classes={"ref": at.ClassCoeffs(c_fixed=7.0)})
    monkeypatch.setenv(at.TABLE_ENV, envt.save(str(tmp_path / "env.json")))
    assert at.resolve_table(None).coeffs("ref").c_fixed == 7.0
    monkeypatch.delenv(at.TABLE_ENV)
    monkeypatch.chdir(tmp_path)             # no ./COST_TABLE.json here
    assert at.resolve_table(None) is at.BUILTIN_TABLE


# --- the knob chooser --------------------------------------------------------

def test_tune_reduces_ncap_padding_on_high_dim_shape():
    # roos_arnold d=10, neval=1e5: n_cubes=1024 so n_cap=102048; the default
    # chunk 16384 rounds n_cap up 12.4%, chunk 8192 only 4.4% — the measured
    # win this PR is built on (BENCH_run.json run/autotune/* rows).
    ig = make_roos_arnold()
    cfg = VegasConfig(neval=100_000, max_it=6, chunk=16_384,
                      execution=ExecutionConfig(autotune=True))
    plan = make_plan(ig, cfg)
    rep = plan.tuned
    assert rep is not None
    assert rep.class_key == at.class_key("ref")   # 'ref@<device_kind>'
    assert plan.cfg.chunk < 16_384
    assert plan.cfg.n_cap < 114_688          # strictly less padded
    assert rep.predicted_s <= rep.predicted_default_s
    assert not plan.execution.autotune       # knobs pinned: replan is cheap
    assert "autotuned[" in plan.describe()


def test_tuned_knobs_survive_replan_for_every_backend():
    # Acceptance: for EVERY registry backend, autotune=True yields a valid
    # plan whose chosen knobs, fed back through make_plan explicitly,
    # reproduce the same resolved geometry (the tuner emits nothing
    # make_plan would reject or renormalize).
    ig = make_cosine(dim=4)
    for backend in available():
        cfg = VegasConfig(neval=4_096, max_it=4, ninc=64,
                          execution=ExecutionConfig(backend=backend,
                                                    autotune=True))
        plan = make_plan(ig, cfg)
        assert plan.tuned is not None, backend
        replan = make_plan(ig, dataclasses.replace(
            VegasConfig(neval=4_096, max_it=4, ninc=64,
                        execution=plan.execution), chunk=plan.cfg.chunk))
        assert replan.cfg.chunk == plan.cfg.chunk, backend
        assert replan.execution.tile == plan.execution.tile, backend
        assert replan.backend.name == backend


def test_tune_family_and_batch_knob():
    fam = make_gaussian_family(np.linspace(0.2, 0.8, 4), dim=10)
    cfg = VegasConfig(neval=50_000, max_it=6, chunk=16_384,
                      execution=ExecutionConfig(autotune=True))
    plan = make_plan(fam, cfg)
    assert plan.tuned is not None
    assert plan.batched              # vmap predicted cheaper than serial
    assert plan.cfg.chunk < 16_384   # same padding win as the single run


def test_autotune_never_loses_an_admissible_plan():
    # Invalid pinned knobs surface make_plan's own PlanError — the tuner
    # must not launder tile=128 on 'ref' into a valid plan...
    ig = make_cosine(dim=4)
    with pytest.raises(PlanError):
        make_plan(ig, VegasConfig(
            neval=4_096, execution=ExecutionConfig(autotune=True, tile=128)))
    # ...and combos that succeed with explicit knobs also succeed tuned
    # (single + family, every backend).
    fam = make_gaussian_family(np.linspace(0.2, 0.8, 3), dim=4)
    for backend in available():
        for workload in (ig, fam):
            explicit = VegasConfig(neval=4_096, ninc=64, execution=
                                   ExecutionConfig(backend=backend))
            make_plan(workload, explicit)          # admissible baseline
            tuned = make_plan(workload, VegasConfig(
                neval=4_096, ninc=64,
                execution=ExecutionConfig(backend=backend, autotune=True)))
            assert tuned.tuned is not None, (backend, workload)


def test_tune_unknown_backend_defers_to_make_plan():
    ig = make_cosine(dim=4)
    cfg = VegasConfig(execution=ExecutionConfig(backend="cuda",
                                                autotune=True))
    with pytest.raises(PlanError, match="cuda"):
        make_plan(ig, cfg)


def test_tune_deterministic():
    ig = make_roos_arnold()
    cfg = VegasConfig(neval=100_000, max_it=6, chunk=16_384,
                      execution=ExecutionConfig(autotune=True))
    a, ra = at.tune(ig, cfg, table=at.BUILTIN_TABLE)
    b, rb = at.tune(ig, cfg, table=at.BUILTIN_TABLE)
    assert a.chunk == b.chunk
    assert dict(ra.chosen) == dict(rb.chosen)
    assert ra.predicted_s == rb.predicted_s


def test_explicit_cost_table_drives_the_choice(tmp_path):
    # A table where scan-step overhead dwarfs eval work must push the
    # chooser to the LARGEST chunk (fewest steps), the opposite of the
    # builtin table's padding-avoidance answer on the same shape.
    ig = make_roos_arnold()
    table = at.CostTable(source="calibrated", classes={
        "ref": at.ClassCoeffs(c_eval_dim=1e-12, c_chunk=1.0)})
    path = table.save(str(tmp_path / "t.json"))
    cfg = VegasConfig(neval=100_000, max_it=6, chunk=16_384,
                      execution=ExecutionConfig(autotune=True,
                                                cost_table=path))
    plan = make_plan(ig, cfg)
    # largest candidate that does not exceed the raw eval capacity
    # (neval + 2*n_cubes = 102048; 131072 is pure padding and filtered out)
    assert plan.cfg.chunk == 65_536
    assert plan.tuned.table_source == path


# --- prediction --------------------------------------------------------------

def test_prediction_monotone_in_neval():
    coeffs = at.BUILTIN_TABLE.coeffs("ref")
    cfg = VegasConfig(max_it=6, chunk=4_096)
    preds = [at.predict_run_s(coeffs, dataclasses.replace(
        cfg, neval=n).resolve(6)) for n in (10_000, 40_000, 160_000)]
    assert preds == sorted(preds)
    assert preds[0] < preds[-1]


def test_prediction_sharding_divides_fill_not_overhead():
    coeffs = at.ClassCoeffs(c_eval_dim=1e-7, c_chunk=1e-3,
                            iter_overhead_s=1e-2)
    rcfg = VegasConfig(neval=65_536, max_it=4, chunk=2_048).resolve(4)
    t1 = at.predict_run_s(coeffs, rcfg, n_shards=1)
    t4 = at.predict_run_s(coeffs, rcfg, n_shards=4)
    assert t4 < t1
    assert t4 > t1 / 4               # replicated adapt does not shrink


# --- the serving layer's shared cost model -----------------------------------

def test_online_cost_min_semantics_and_prior():
    table = at.CostTable(source="calibrated", classes={
        "ref": at.ClassCoeffs(c_fixed=1e-3, iter_overhead_s=2e-3)})
    cost = at.OnlineCost(table=table)
    rcfg = VegasConfig(neval=8_192, chunk=2_048).resolve(4)
    key = ("k",)
    # no observation yet: the table is the prior (needs the plan geometry)
    assert cost.unit(key) is None
    prior = cost.unit(key, rcfg=rcfg)
    assert prior == pytest.approx(
        table.coeffs("ref").iteration_s(
            b=1, d=rcfg.dim, n_cap=rcfg.n_cap,
            n_chunks=rcfg.n_cap // rcfg.chunk))
    # observations take over and keep the MINIMUM ever seen
    cost.observe(key, 0.5)
    cost.observe(key, 0.2)
    cost.observe(key, 0.9)
    assert cost.unit(key, rcfg=rcfg) == 0.2
    assert cost.classes_calibrated == 1
    assert cost.snapshot() == {"k": 0.2}
    # and without a table, unobserved classes stay uncalibrated (legacy)
    assert at.OnlineCost().unit(key, rcfg=rcfg) is None


def test_serve_consumes_table_as_budget_prior(tmp_path):
    from repro.serve import IntegrationRequest, SweepService
    # A table claiming ~1s per scenario-iteration: a 5ms budget must cap
    # the FIRST batch of a never-before-seen class at min_trips — before
    # any observation exists (the legacy model cannot cap batch one).
    table = at.CostTable(source="calibrated", classes={
        "ref": at.ClassCoeffs(c_fixed=1.0)})
    path = table.save(str(tmp_path / "t.json"))
    with SweepService(cost_table=path) as svc:
        assert svc.stats()["cost_model"]["table"] == path
        t = svc.submit(IntegrationRequest(
            family="gaussian", params=[0.5], neval=500, max_it=8, ninc=32,
            chunk=500, time_budget_s=5e-3, seed=0))
        r = t.result(timeout=120)
    assert r.capped
    assert int(r.n_it_used[0]) < 8


# --- the benchmark gate ------------------------------------------------------

def _row(name, us, interpret=None, chunk=None):
    return {"name": name, "us_per_call": us, "interpret": interpret,
            "chunk": chunk}


def test_gate_run_pairing():
    from benchmarks.run import gate_run
    ok = [_row("run/autotune/a/default", 100.0),
          _row("run/autotune/a/autotuned", 80.0)]
    assert gate_run(ok) == []
    # within the 5% noise allowance but never faster anywhere -> one failure
    noise = [_row("run/autotune/a/default", 100.0),
             _row("run/autotune/a/autotuned", 104.0)]
    assert any("won on none" in f for f in gate_run(noise))
    # slower beyond tolerance -> named failure
    slow = ok + [_row("run/autotune/b/default", 100.0),
                 _row("run/autotune/b/autotuned", 120.0)]
    assert any("run/autotune/b" in f for f in gate_run(slow))
    # cross-mode pairs are skipped, and a gate with nothing measured fails
    cross = [_row("run/autotune/a/default", 100.0, interpret=True),
             _row("run/autotune/a/autotuned", 500.0, interpret=False)]
    assert any("nothing to check" in f for f in gate_run(cross))
    assert any("nothing to check" in f for f in gate_run([]))
    # unrelated run/* rows never pair
    assert any("nothing to check" in f
               for f in gate_run([_row("run/roos_arnold/ref", 50.0)]))


def test_gate_abs_pairing():
    from benchmarks.run import gate_abs

    def row(name, us, dk, backend="pallas_gpu", interpret=False):
        return {"name": name, "us_per_call": us, "device_kind": dk,
                "backend": backend, "interpret": interpret}

    a100 = "NVIDIA A100-SXM4-40GB"
    prior = [row("f/x", 100.0, a100), row("f/x", 90.0, a100),  # best = 90
             row("f/y", 100.0, None)]                          # legacy row
    # within threshold vs the BEST prior -> checked, no failure
    fails, checked, skipped = gate_abs([row("f/x", 98.0, a100)], prior)
    assert (fails, checked, skipped) == ([], 1, 0)
    # regression beyond 1.10x -> named failure with the ratio
    fails, checked, _ = gate_abs([row("f/x", 120.0, a100)], prior)
    assert checked == 1 and any("1.33x" in f and "f/x" in f for f in fails)
    # a legacy (unstamped) prior matches any REAL device_kind
    fails, checked, _ = gate_abs([row("f/y", 99.0, a100)], prior)
    assert (fails, checked) == ([], 1)
    # generic-cpu rows and no-prior rows are skipped, never failed
    fails, checked, skipped = gate_abs(
        [row("f/x", 500.0, "cpu"), row("f/x", 500.0, None),
         row("f/new", 500.0, a100)], prior)
    assert (fails, checked, skipped) == ([], 0, 3)
    # interpret mode is part of the pairing key
    fails, checked, skipped = gate_abs(
        [row("f/x", 500.0, a100, interpret=True)], prior)
    assert (fails, checked, skipped) == ([], 0, 1)


def test_emit_rows_carry_device_kind():
    from benchmarks import common
    common.reset_rows()
    try:
        common.emit("x/y", 1e-3, backend="ref", chunk=128)
        row = common.ROWS[-1]
        assert row["device_kind"] == jax.devices()[0].device_kind
        assert row["chunk"] == 128
    finally:
        common.reset_rows()


# --- steady-state program reuse ----------------------------------------------

def test_make_single_program_is_replayable():
    from repro.core import integrator as core
    from repro.engine.executor import make_single_program
    ig = make_cosine(dim=4)
    plan = make_plan(ig, VegasConfig(neval=4_096, max_it=4, ninc=64))
    prog = make_single_program(plan)
    state = core.init_state(ig, plan.cfg, jax.random.PRNGKey(0))
    out1 = prog(state)
    out2 = prog(state)               # non-donating: the input state survives
    np.testing.assert_array_equal(np.asarray(out1.results),
                                  np.asarray(out2.results))
    fam = make_gaussian_family(np.linspace(0.2, 0.8, 2), dim=2)
    with pytest.raises(ValueError, match="family"):
        make_single_program(make_plan(fam, VegasConfig(neval=2_048)))
