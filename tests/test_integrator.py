"""Integration tests: does the integrator integrate (paper §4 claims at
test scale)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import VegasConfig, run
from repro.core import integrands as igs


FAST = VegasConfig(neval=60_000, max_it=12, skip=4, ninc=128, chunk=16384)


@pytest.mark.parametrize("maker,kw", [
    (igs.make_sine_exp, {}),
    (igs.make_linear, {}),
    (igs.make_cosine, {}),
    (igs.make_roos_arnold, {}),
    (igs.make_morokoff_caflisch, {}),
])
def test_table3_easy_integrands_converge(maker, kw):
    ig = maker(**kw)
    r = run(ig, FAST, key=jax.random.PRNGKey(7))
    pull = (r.mean - ig.target) / r.sdev
    assert abs(pull) < 5, (ig.name, r, ig.target)
    assert r.sdev / abs(ig.target) < 5e-2


def test_peaked_gaussian_converges_with_adaptation():
    ig = igs.make_gaussian()
    cfg = VegasConfig(neval=300_000, max_it=12, skip=5, ninc=256, chunk=65536)
    r = run(ig, cfg, key=jax.random.PRNGKey(1))
    pull = (r.mean - ig.target) / r.sdev
    assert abs(pull) < 5
    assert r.chi2_dof < 5


def test_ridge_stratification_beats_uniform():
    """Paper Fig. 8: adaptive stratification (beta>0) reduces the variance on
    diagonal-structured integrands vs beta=0 (classic VEGAS / m-CUBES)."""
    ig = igs.make_ridge(n_peaks=50)
    kw = dict(neval=80_000, max_it=12, skip=5, ninc=128, chunk=16384)
    r_plus = run(ig, VegasConfig(beta=0.75, **kw), key=jax.random.PRNGKey(3))
    r_zero = run(ig, VegasConfig(beta=0.0, **kw), key=jax.random.PRNGKey(3))
    assert abs(r_plus.mean - ig.target) / r_plus.sdev < 5
    # stratified sdev should not be worse; typically clearly better.
    assert r_plus.sdev < 1.5 * r_zero.sdev


def test_iteration_aggregation_weights_by_variance():
    from repro.core.integrator import combine_results
    res = jnp.array([[1.0, 1e-4], [3.0, 1e-2]])  # second has 100x variance
    mean, sdev, chi2, n = combine_results(res, skip=0, n_done=2)
    assert abs(float(mean) - (1.0 / 1e-4 + 3.0 / 1e-2) / (1 / 1e-4 + 1 / 1e-2)) < 1e-6
    assert float(sdev) == pytest.approx(np.sqrt(1.0 / (1 / 1e-4 + 1 / 1e-2)), rel=1e-5)
    assert int(n) == 2


def test_combine_results_all_unusable_is_nan_free():
    """Every iteration has inf/non-finite sig2 (wsum == 0): the combination
    must return the (0.0, inf, 0.0, 0) sentinel, never NaN."""
    from repro.core.integrator import combine_results
    for bad in (np.inf, np.nan, 0.0):
        res = jnp.array([[1.0, bad], [2.0, bad], [3.0, bad]])
        mean, sdev, chi2, n = combine_results(res, skip=0, n_done=3)
        assert float(mean) == 0.0
        assert float(sdev) == np.inf
        assert float(chi2) == 0.0
        assert int(n) == 0
        assert not np.isnan(float(mean))
        assert not np.isnan(float(chi2))


def test_combine_results_skip_beyond_n_done_is_nan_free():
    from repro.core.integrator import combine_results
    res = jnp.array([[1.0, 1e-4], [2.0, 1e-4]])
    mean, sdev, chi2, n = combine_results(res, skip=5, n_done=2)
    assert (float(mean), float(chi2), int(n)) == (0.0, 0.0, 0)
    assert float(sdev) == np.inf


def test_skip_excludes_warmup():
    from repro.core.integrator import combine_results
    res = jnp.array([[100.0, 1e-6], [1.0, 1e-4], [1.0, 1e-4]])
    mean, _, _, n = combine_results(res, skip=1, n_done=3)
    assert abs(float(mean) - 1.0) < 1e-6
    assert int(n) == 2


def test_resume_from_state_matches_uninterrupted():
    """Fault-tolerance: stop after k iterations, resume from the state, and
    get the SAME final answer as the uninterrupted run."""
    ig = igs.make_cosine(dim=4)
    cfg = VegasConfig(neval=20_000, max_it=8, skip=2, ninc=64, chunk=4096)
    key = jax.random.PRNGKey(11)
    full = run(ig, cfg, key=key)

    cfg_half = VegasConfig(neval=20_000, max_it=4, skip=2, ninc=64, chunk=4096)
    half = run(ig, cfg_half, key=key)
    resumed = run(ig, cfg, key=key, state=half.state)
    assert resumed.mean == pytest.approx(full.mean, rel=1e-6)
    assert resumed.sdev == pytest.approx(full.sdev, rel=1e-6)


def test_pallas_backend_statistically_consistent():
    ig = igs.make_cosine(dim=4)
    kw = dict(neval=20_000, max_it=8, skip=3, ninc=64, chunk=4096)
    r = run(ig, VegasConfig(backend="pallas", **kw), key=jax.random.PRNGKey(5))
    pull = (r.mean - ig.target) / r.sdev
    assert abs(pull) < 5


def test_importance_only_mode():
    # nstrat=1: single cube, pure adaptive importance sampling (VEGAS map only)
    ig = igs.make_gaussian(dim=2, sigma=0.1)
    cfg = VegasConfig(neval=40_000, max_it=10, skip=4, ninc=128, nstrat=1,
                      chunk=8192)
    r = run(ig, cfg, key=jax.random.PRNGKey(2))
    assert abs(r.mean - ig.target) / r.sdev < 5
