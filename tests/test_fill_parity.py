"""Three-way backend parity sweep: ``fill_pallas`` (interpret mode, both
the P-V2 baseline and the P-V3 fused streaming kernel) AND the Triton-
structured ``fill_pallas_gpu`` scatter kernel vs ``fill_reference`` across
dimensions, stratification counts, and non-power-of-two chunk/tile shapes.

All paths share the chunk-keyed RNG contract (DESIGN.md C5) — the in-kernel
backends regenerate the stream bit-for-bit — so they draw IDENTICAL
samples: tolerances cover accumulation-order f32 drift only, never
sampling differences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fill as fill_mod
from repro.core import map as vmap_
from repro.core import strat


def _ig(x):
    return jnp.prod(1.0 / (0.1 + (x - 0.3) ** 2), axis=-1)


def _assert_fill_parity(dim, nstrat, chunk, n_chunks, tile, ninc=32,
                        adapted=True, neval=None):
    n_cubes = nstrat**dim
    n_cap = chunk * n_chunks
    key = jax.random.PRNGKey(dim * 100 + nstrat)
    if adapted:
        # a non-uniform (adapted-looking) map stresses the gather paths
        w = jax.random.uniform(jax.random.fold_in(key, 1), (dim, ninc),
                               minval=0.05, maxval=1.0)
        w = w / w.sum(1, keepdims=True)
        edges = jnp.concatenate(
            [jnp.zeros((dim, 1)), jnp.cumsum(w, axis=1)], axis=1)
    else:
        edges = vmap_.uniform_edges([0.0] * dim, [1.0] * dim, ninc)
    if neval is None:
        neval = max(n_cap - n_cubes, n_cubes * 2)
    n_h = strat.uniform_nh(neval, n_cubes)

    ref = fill_mod.fill_reference(edges, n_h, key, _ig, nstrat=nstrat,
                                  n_cap=n_cap, chunk=chunk)
    # (fused, rng_in_kernel): P-V2 baseline, P-V3 hybrid (CPU default), and
    # P-V3 with in-kernel RNG (the compiled-TPU program, run interpreted).
    for fused, rng in ((False, None), (True, None), (True, True)):
        pal = fill_mod.fill_pallas(edges, n_h, key, _ig, nstrat=nstrat,
                                   n_cap=n_cap, chunk=chunk, interpret=True,
                                   fused_cubes=fused, tile=tile,
                                   rng_in_kernel=rng)
        for field in ("map_sums", "map_counts", "cube_s1", "cube_s2"):
            a = np.asarray(getattr(ref, field))
            b = np.asarray(getattr(pal, field))
            scale = np.abs(a).max() or 1.0
            np.testing.assert_allclose(
                b, a, rtol=1e-4, atol=1e-5 * scale,
                err_msg=f"{field} fused={fused} rng_in_kernel={rng} dim={dim} "
                        f"nstrat={nstrat} chunk={chunk} tile={tile}")
    # The GPU scatter kernel rides the same sweep: hybrid (host uniforms)
    # and in-kernel RNG (the compiled-Triton program, run interpreted).
    # block=tile reuses each case's deliberately awkward step size; the
    # wrapper's divisor fallback (_pick_block) absorbs non-divisors.
    for rng in (None, True):
        gpu = fill_mod.fill_pallas_gpu(edges, n_h, key, _ig, nstrat=nstrat,
                                       n_cap=n_cap, chunk=chunk,
                                       interpret=True, block=tile,
                                       rng_in_kernel=rng)
        for field in ("map_sums", "map_counts", "cube_s1", "cube_s2"):
            a = np.asarray(getattr(ref, field))
            b = np.asarray(getattr(gpu, field))
            scale = np.abs(a).max() or 1.0
            np.testing.assert_allclose(
                b, a, rtol=1e-4, atol=1e-5 * scale,
                err_msg=f"{field} backend=pallas-gpu rng_in_kernel={rng} "
                        f"dim={dim} nstrat={nstrat} chunk={chunk} "
                        f"block<={tile}")


@pytest.mark.parametrize("dim", [1, 2, 4])
@pytest.mark.parametrize("nstrat", [1, 2, 5])
def test_fill_parity_dim_nstrat_sweep(dim, nstrat):
    _assert_fill_parity(dim, nstrat, chunk=512, n_chunks=2, tile=256)


@pytest.mark.parametrize("chunk,n_chunks,tile", [
    (96, 3, 256),    # n_local=288 not a tile multiple -> divisor fallback (96)
    (384, 2, 256),   # tile | n_local but not chunk: tiles cross chunk bounds
    (100, 4, 50),    # nothing a power of two
    (768, 1, 256),   # single chunk, exact tiling
])
def test_fill_parity_non_pow2_chunk_tile(chunk, n_chunks, tile):
    _assert_fill_parity(dim=2, nstrat=3, chunk=chunk, n_chunks=n_chunks,
                        tile=tile)


def test_fill_parity_uniform_map_exactish():
    """Uniform map + nstrat=1: the transform is the identity; the two
    backends agree to strict tolerance."""
    _assert_fill_parity(dim=2, nstrat=1, chunk=256, n_chunks=2, tile=128,
                        adapted=False)


def test_fill_parity_odd_chunk_times_dim():
    """chunk*d odd exercises the padded-counter branch of the in-kernel RNG
    (jax pads one zero before splitting the iota into cipher halves)."""
    _assert_fill_parity(dim=3, nstrat=2, chunk=45, n_chunks=3, tile=45)


def test_fill_parity_masked_tail_heavy():
    """Most of the eval axis past the active total: whole tiles of overflow
    evals at the n_cap pad must contribute exactly zero in every backend."""
    dim, nstrat, chunk, n_chunks = 2, 3, 256, 4
    n_cubes = nstrat**dim
    # active total ~ one third of n_cap: the last ~2.7 chunks are all-masked
    _assert_fill_parity(dim, nstrat, chunk, n_chunks, tile=64,
                        neval=max(chunk * n_chunks // 3, 2 * n_cubes))


def test_fill_parity_cubes_not_tile_multiple():
    """n_cubes (3^4 = 81) far from any tile multiple: the fused kernel's
    LANE-padded accumulator must trim back to exactly n_cubes."""
    _assert_fill_parity(dim=4, nstrat=3, chunk=512, n_chunks=2, tile=128)


@pytest.mark.parametrize("fused", [False, True])
def test_backend_configs_agree_through_full_run(fused):
    """End-to-end: a full adapted run under each backend lands within
    combined statistical error (identical streams, different accumulation)."""
    from repro.core import VegasConfig, run
    from repro.core import integrands as igs
    ig = igs.make_cosine(dim=3)
    kw = dict(neval=12_000, max_it=6, skip=2, ninc=32, chunk=4096)
    r_ref = run(ig, VegasConfig(backend="ref", **kw), key=jax.random.PRNGKey(4))
    r_pal = run(ig, VegasConfig(backend="pallas", fused_cubes=fused, **kw),
                key=jax.random.PRNGKey(4))
    comb = float(np.hypot(r_ref.sdev, r_pal.sdev))
    assert abs(r_ref.mean - r_pal.mean) < 3 * comb
