"""Plan-time validation of the grad axis (§11): every unsupported
GradPolicy combination must die as a one-line PlanError naming the fix —
never as a tracer error from inside the custom-AD machinery."""

import types

import jax.numpy as jnp
import pytest

from repro.core import VegasConfig
from repro.core.integrands import Integrand
from repro.engine import (CheckpointPolicy, ExecutionConfig, GradPolicy,
                          PlanError, execute, make_plan)

IG = Integrand("flat", 2, lambda x: jnp.ones(x.shape[:-1]),
               (0.0, 0.0), (1.0, 1.0), target=1.0)
FAST = VegasConfig(neval=1_000, max_it=2, ninc=16, chunk=512)


def _plan(**exec_kw):
    return make_plan(IG, FAST, execution=ExecutionConfig(**exec_kw))


def test_grad_rejects_fused_in_kernel_rng():
    with pytest.raises(PlanError, match="in-kernel") as ei:
        _plan(backend="pallas-fused", grad=GradPolicy())
    # The error names the capable backends, not just the failure.
    assert "ref" in str(ei.value) and "pallas" in str(ei.value)


def test_score_mode_rejects_pallas():
    """score needs the sample-level surrogate rewrite => ref only."""
    with pytest.raises(PlanError, match="grad-score"):
        _plan(backend="pallas", grad=GradPolicy(mode="score"))
    # pathwise on the same backend is fine (value/cotangent pairing).
    assert _plan(backend="pallas",
                 grad=GradPolicy()).grad.mode == "pathwise"


def test_grad_rejects_checkpoint():
    with pytest.raises(PlanError, match="grad \\+ checkpoint"):
        _plan(grad=GradPolicy(),
              checkpoint=CheckpointPolicy(directory="/tmp/x"))


def test_grad_rejects_mesh():
    """A >1-shard mesh cannot carry the differentiable eval pass yet.  The
    check is pure plan arithmetic (mesh.shape products), so a duck-typed
    2-device mesh exercises it on a 1-device CPU host."""
    fake_mesh = types.SimpleNamespace(axis_names=("dev",), shape={"dev": 2})
    with pytest.raises(PlanError, match="grad \\+ mesh"):
        _plan(grad=GradPolicy(), mesh=fake_mesh, shard_axes=("dev",))


def test_grad_rejects_bogus_mode():
    with pytest.raises(PlanError, match="not one of"):
        _plan(grad=GradPolicy(mode="adjoint"))


def test_grad_off_normalizes_to_plain_plan():
    """mode='off' is inert — the plan drops the policy and the run is the
    ordinary (non-grad) program, mirroring the inert-StopPolicy rule."""
    plan = _plan(grad=GradPolicy(mode="off"))
    assert plan.grad is None
    res = execute(plan)
    assert hasattr(res, "chi2_dof")  # a VegasResult, not a GradResult


def test_plan_describe_shows_grad_axis():
    plan = _plan(grad=GradPolicy(mode="pathwise", with_sdev=True))
    text = plan.describe()
    assert "grad" in text and "pathwise,with_sdev" in text
    assert "two-phase" in text
    off = _plan()
    assert "grad       off" in off.describe()


def test_execution_config_describe_shows_grad():
    ec = ExecutionConfig(grad=GradPolicy(mode="score", with_sdev=False))
    assert "grad[score]" in ec.describe()
    assert "grad" not in ExecutionConfig().describe()


def test_cli_plan_shows_grad_axis(capsys):
    """--plan --grad pathwise prints the validated grad line and returns
    the plan without running anything."""
    from repro.launch.integrate import main
    plan = main(["--integrand", "gaussian", "--neval", "1000",
                 "--iters", "2", "--plan", "--grad", "pathwise"])
    assert plan.grad is not None and plan.grad.mode == "pathwise"
    out = capsys.readouterr().out
    assert "grad" in out and "two-phase" in out


def test_cli_rejects_grad_fused_backend():
    from repro.launch.integrate import main
    with pytest.raises(PlanError, match="in-kernel"):
        main(["--integrand", "gaussian", "--neval", "1000", "--iters", "2",
              "--plan", "--grad", "pathwise", "--backend", "pallas-fused"])
