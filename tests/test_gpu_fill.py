"""The pallas-gpu backend (ISSUE 9, DESIGN.md §14): block knob model and
autotune, in-kernel RNG bit-exactness vs the host stream, ref-oracle parity
at scatter-stressing shapes, shard/vmap composition, the PlanError matrix
for its capability/knob declarations, and the platform-default resolution
(``backend='auto'``).

Everything here runs the kernel through the Pallas INTERPRETER on CPU (the
grid executes sequentially, atomics degenerate to plain adds, results are
deterministic); the compiled-Triton path needs real GPU silicon and
auto-skips with an explicit reason.  The parity sweep proper (ref vs
pallas-fused vs pallas-gpu across shapes) lives in test_fill_parity.py.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels as K
from repro.core import VegasConfig
from repro.core import fill as fill_mod
from repro.core import strat
from repro.core import integrands as igs
from repro.engine import ExecutionConfig, PlanError, make_plan
from repro.kernels import gpu_fill

requires_gpu = pytest.mark.skipif(
    jax.default_backend() != "gpu",
    reason="compiled pallas-gpu needs a GPU backend (jax.default_backend()"
           f"={jax.default_backend()!r}); interpret-mode coverage of the "
           "same program runs on CPU in this suite")


def _ig(x):
    return jnp.prod(1.0 / (0.1 + (x - 0.3) ** 2), axis=-1)


def _setup(dim=3, nstrat=3, ninc=32, neval=None, seed=7):
    n_cubes = nstrat**dim
    key = jax.random.PRNGKey(seed)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (dim, ninc),
                           minval=0.05, maxval=1.0)
    w = w / w.sum(1, keepdims=True)
    edges = jnp.concatenate(
        [jnp.zeros((dim, 1)), jnp.cumsum(w, axis=1)], axis=1)
    n_h = strat.uniform_nh(neval or 4 * n_cubes, n_cubes)
    return edges, n_h, key, n_cubes


# --- the block knob model ----------------------------------------------------

def test_valid_blocks_are_divisors_within_budget():
    blocks = gpu_fill.valid_blocks(768, d=4, ninc=64)
    assert blocks == sorted(blocks)
    for b in blocks:
        assert 768 % b == 0
        assert gpu_fill.block_footprint_bytes(b, 4, 64) <= gpu_fill.SMEM_BUDGET
    # every divisor NOT listed busts the budget or the max_block cap
    rejected = [b for b in range(1, 769)
                if 768 % b == 0 and b not in blocks]
    for b in rejected:
        assert (gpu_fill.block_footprint_bytes(b, 4, 64) > gpu_fill.SMEM_BUDGET
                or b > 1024)


def test_autotune_block_prefers_pow2_and_respects_budget():
    b = gpu_fill.autotune_block(1024, d=4, ninc=64)
    assert 1024 % b == 0 and (b & (b - 1)) == 0
    assert gpu_fill.block_footprint_bytes(b, 4, 64) <= gpu_fill.SMEM_BUDGET
    # a tiny budget forces a smaller block, never an invalid one
    small = gpu_fill.autotune_block(1024, d=4, ninc=64, budget=16 << 10)
    assert small < b and 1024 % small == 0


def test_pick_block_divisor_fallback_and_diagnostic():
    assert gpu_fill._pick_block(256, 384, 2, 32) == 192   # largest divisor
    assert gpu_fill._pick_block(512, 256, 2, 32) == 256   # clipped to chunk
    assert gpu_fill._pick_block(None, 512, 2, 32) >= 8    # autotuned
    with pytest.raises(ValueError, match="divisor"):
        gpu_fill._pick_block(1, 509, 2, 32)               # 509 prime, block 1


# --- RNG contract ------------------------------------------------------------

def test_in_kernel_rng_bit_exact_with_host_stream():
    """rng_in_kernel=True (the compiled-GPU program, run interpreted) must
    reproduce the host-uniform path BIT-FOR-BIT — under whichever
    jax_threefry_partitionable layout conftest selected (CI runs both)."""
    edges, n_h, key, _ = _setup(dim=3, nstrat=2, ninc=16)
    kw = dict(nstrat=2, n_cap=270, chunk=90, interpret=True, block=45)
    host = gpu_fill.fill(edges, n_h, key, _ig, rng_in_kernel=False, **kw)
    kern = gpu_fill.fill(edges, n_h, key, _ig, rng_in_kernel=True, **kw)
    also = gpu_fill.fill(edges, n_h, key, _ig, rng_in_kernel=True,
                         num_warps=4, **kw)    # compiler knob: no effect
    for field in ("map_sums", "map_counts", "cube_s1", "cube_s2"):
        np.testing.assert_array_equal(np.asarray(getattr(host, field)),
                                      np.asarray(getattr(kern, field)),
                                      err_msg=field)
        np.testing.assert_array_equal(np.asarray(getattr(kern, field)),
                                      np.asarray(getattr(also, field)),
                                      err_msg=f"{field} (num_warps)")


# --- oracle parity + composition ---------------------------------------------

def _assert_close(a, b, field, **ctx):
    a, b = np.asarray(a), np.asarray(b)
    scale = np.abs(a).max() or 1.0
    np.testing.assert_allclose(b, a, rtol=1e-4, atol=1e-5 * scale,
                               err_msg=f"{field} {ctx}")


def test_parity_vs_ref_cubes_not_block_multiple():
    """n_cubes=27 with block=32: window flushes straddle the padded tail of
    the flat accumulator; the wrapper must trim back to exactly n_cubes."""
    edges, n_h, key, _ = _setup(dim=3, nstrat=3, ninc=32)
    kw = dict(nstrat=3, n_cap=512, chunk=256)
    ref = fill_mod.fill_reference(edges, n_h, key, _ig, **kw)
    gpu = gpu_fill.fill(edges, n_h, key, _ig, interpret=True, block=32, **kw)
    for field in ("map_sums", "map_counts", "cube_s1", "cube_s2"):
        _assert_close(getattr(ref, field), getattr(gpu, field), field)


def test_shard_split_kahan_invariance():
    """C5 contract: two half-range fills (kahan, like the sharded path) sum
    to the one-shot full-range fill."""
    edges, n_h, key, _ = _setup(dim=2, nstrat=3, ninc=16)
    kw = dict(nstrat=3, n_cap=512, chunk=128, interpret=True, block=64,
              kahan=True)
    whole = gpu_fill.fill(edges, n_h, key, _ig, **kw)
    lo = gpu_fill.fill(edges, n_h, key, _ig, start_chunk=0, n_chunks=2, **kw)
    hi = gpu_fill.fill(edges, n_h, key, _ig, start_chunk=2, n_chunks=2, **kw)
    both = lo + hi
    for field in ("map_sums", "map_counts", "cube_s1", "cube_s2"):
        _assert_close(getattr(whole, field), getattr(both, field), field)


def test_vmap_over_closure_params():
    """CLOSURE_HOISTING + VMAPPABLE: a parameterized integrand vmaps over
    its captured array and matches per-scenario serial fills."""
    edges, n_h, key, _ = _setup(dim=2, nstrat=2, ninc=16)
    kw = dict(nstrat=2, n_cap=256, chunk=128, interpret=True, block=32)
    amps = jnp.asarray([0.5, 2.0])

    def fill_for(a):
        return gpu_fill.fill(edges, n_h, key,
                             lambda x: a * _ig(x), **kw)
    batched = jax.vmap(fill_for)(amps)
    for i, a in enumerate(amps):
        single = fill_for(a)
        for field in ("map_sums", "cube_s1", "cube_s2"):
            _assert_close(getattr(single, field),
                          getattr(batched, field)[i], field, scenario=i)


def test_engine_run_and_early_stop():
    """End-to-end through the registry: a pallas-gpu run completes, and an
    active StopPolicy (EARLY_STOP capability) traces through the
    while_loop."""
    from repro.core import run
    from repro.engine import StopPolicy, execute
    ig = igs.make_cosine(dim=2)
    r = run(ig, VegasConfig(neval=4_000, max_it=3, ninc=16, chunk=2048,
                            execution=ExecutionConfig(backend="pallas-gpu")),
            key=jax.random.PRNGKey(0))
    assert np.isfinite(r.mean) and r.n_it == 3
    plan = make_plan(ig, VegasConfig(
        neval=4_000, max_it=5, ninc=16, chunk=2048,
        execution=ExecutionConfig(backend="pallas-gpu", block=64,
                                  stop=StopPolicy(rtol=0.5))))
    res = execute(plan, key=jax.random.PRNGKey(1))
    assert np.isfinite(res.mean)


# --- the PlanError matrix ----------------------------------------------------

FAST = VegasConfig(neval=2_048, max_it=2, ninc=16, chunk=1024)
IG = igs.make_cosine(dim=2)


def test_plan_rejects_f64():
    with pytest.raises(PlanError, match="float32.*float64"):
        make_plan(IG, dataclasses.replace(FAST, dtype="float64"),
                  execution=ExecutionConfig(backend="pallas-gpu"))


@pytest.mark.parametrize("mode", ["pathwise", "score"])
def test_plan_rejects_grad(mode):
    from repro.engine import GradPolicy
    with pytest.raises(PlanError, match=f"grad-{mode}"):
        make_plan(IG, FAST, execution=ExecutionConfig(
            backend="pallas-gpu", grad=GradPolicy(mode=mode)))


def test_plan_rejects_cross_backend_knobs():
    with pytest.raises(PlanError, match="tile.*not a knob.*pallas-gpu"):
        make_plan(IG, FAST, execution=ExecutionConfig(backend="pallas-gpu",
                                                      tile=64))
    with pytest.raises(PlanError, match="block.*not a knob.*'ref'"):
        make_plan(IG, FAST, execution=ExecutionConfig(backend="ref",
                                                      block=64))
    with pytest.raises(PlanError, match="num_warps.*not a knob"):
        make_plan(IG, FAST, execution=ExecutionConfig(backend="pallas-fused",
                                                      num_warps=4))


def test_plan_allows_vmap_shard_stop_and_knobs():
    from repro.batch.family import make_gaussian_family
    fam = make_gaussian_family(np.array([0.3, 0.7]))
    plan = make_plan(fam, FAST, execution=ExecutionConfig(
        backend="pallas-gpu", batch="vmap", block=64, num_warps=4))
    assert plan.batched and plan.backend.name == "pallas-gpu"
    from repro.launch.mesh import make_local_mesh
    plan = make_plan(IG, FAST, execution=ExecutionConfig(
        backend="pallas-gpu", mesh=make_local_mesh()))
    assert plan.backend.supports("shardable")


# --- platform default / auto resolution --------------------------------------

def test_backend_default_registry_names():
    assert K.PLATFORM_BACKENDS == {"tpu": "pallas-fused", "gpu": "pallas-gpu"}
    assert K.backend_default() == K.PLATFORM_BACKENDS.get(
        jax.default_backend(), "ref")


def test_auto_backend_resolves_in_plan():
    plan = make_plan(IG, FAST, execution=ExecutionConfig(backend="auto"))
    assert plan.backend.name == K.backend_default()
    assert plan.execution.backend == plan.backend.name  # recorded, not 'auto'
    # auto + autotune: the tuner sees the concrete backend
    plan = make_plan(IG, FAST, execution=ExecutionConfig(backend="auto",
                                                         autotune=True))
    assert plan.tuned is not None
    assert plan.backend.name == K.backend_default()


# --- compiled-hardware path --------------------------------------------------

@requires_gpu
def test_compiled_gpu_matches_ref():
    """On real GPU silicon only: the compiled Triton kernel (float atomics,
    parallel grid) must agree with the f32 oracle to accumulation-order
    tolerance."""
    edges, n_h, key, _ = _setup(dim=3, nstrat=3, ninc=32)
    kw = dict(nstrat=3, n_cap=4096, chunk=1024)
    ref = fill_mod.fill_reference(edges, n_h, key, _ig, **kw)
    gpu = gpu_fill.fill(edges, n_h, key, _ig, interpret=False, **kw)
    for field in ("map_sums", "map_counts", "cube_s1", "cube_s2"):
        a = np.asarray(getattr(ref, field))
        b = np.asarray(getattr(gpu, field))
        scale = np.abs(a).max() or 1.0
        np.testing.assert_allclose(b, a, rtol=5e-4, atol=5e-5 * scale,
                                   err_msg=field)
