"""Adaptive early stopping regressions (ISSUE 5, DESIGN.md §10).

The stop-policy while_loop must be the SAME program as the fixed fori_loop,
just shorter: its executed prefix is bitwise identical to the fixed run,
the stop respects ``min_it``, the vmapped per-scenario masks reproduce the
serial per-scenario trip counts exactly, resume re-derives the running stop
statistics from the carried results buffer, and `combine_results` ignores
the ``sigma2 = inf`` sentinels of never-executed iterations for every
``n_done < max_it``.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.engine as E
from repro.batch import run_batch, run_serial
from repro.batch.family import IntegrandFamily
from repro.core import VegasConfig, run
from repro.core import integrands as igs
from repro.core import integrator as core

KEY = jax.random.PRNGKey(21)
KW = dict(neval=12_000, max_it=10, skip=2, ninc=32, chunk=4096)


def _stop_cfg(rtol=0.0, atol=0.0, min_it=2, **kw):
    return VegasConfig(execution=E.ExecutionConfig(
        stop=E.StopPolicy(rtol=rtol, atol=atol, min_it=min_it)), **(KW | kw))


def make_hetero_gaussian(sigmas, dim=2, mu=0.5) -> IntegrandFamily:
    """Product Gaussians of per-scenario WIDTH: broad scenarios converge in
    a couple of iterations, sharp ones keep adapting — the heterogeneity
    the per-scenario stop masks exist for."""
    sigmas = np.asarray(sigmas, np.float64)

    def fn(sigma, x):
        norm = (2.0 * math.pi * sigma**2) ** (-dim / 2.0)
        return norm * jnp.exp(
            -jnp.sum((x - mu) ** 2, axis=-1) / (2.0 * sigma**2))

    targets = np.array([
        (math.erf((1.0 - mu) / (s * math.sqrt(2.0))) / 2.0
         + math.erf(mu / (s * math.sqrt(2.0))) / 2.0) ** dim
        for s in sigmas])
    return IntegrandFamily("hetero_gaussian", dim, fn, (0.0,) * dim,
                           (1.0,) * dim, jnp.asarray(sigmas, jnp.float32),
                           targets)


# --- single scenario ---------------------------------------------------------

def test_while_loop_prefix_is_bitwise_fixed_loop():
    """A loose-rtol run stops mid-loop, and everything it DID execute is
    bit-identical to the fixed-length run: the results prefix, and the
    full state of a fixed run truncated at exactly n_it_used."""
    ig = igs.make_cosine(dim=3)
    r_stop = run(ig, _stop_cfg(rtol=0.02), key=KEY)
    n = r_stop.n_it_used
    assert 2 <= n < KW["max_it"], r_stop
    assert int(r_stop.state.it) == n

    r_fixed = run(ig, VegasConfig(**KW), key=KEY)
    assert r_fixed.n_it_used == KW["max_it"]
    np.testing.assert_array_equal(np.asarray(r_stop.state.results[:n]),
                                  np.asarray(r_fixed.state.results[:n]))
    # slots past n keep the init sentinels: never executed, not zeroed
    np.testing.assert_array_equal(
        np.asarray(r_stop.state.results[n:, 1]),
        np.full(KW["max_it"] - n, np.inf, np.float32))

    r_trunc = run(ig, VegasConfig(**{**KW, "max_it": n}), key=KEY)
    np.testing.assert_array_equal(np.asarray(r_stop.state.edges),
                                  np.asarray(r_trunc.state.edges))
    np.testing.assert_array_equal(np.asarray(r_stop.state.n_h),
                                  np.asarray(r_trunc.state.n_h))
    assert r_stop.mean == r_trunc.mean and r_stop.sdev == r_trunc.sdev


def test_stop_never_triggers_before_min_it():
    ig = igs.make_cosine(dim=2)
    # rtol so loose the very first combined estimate satisfies it
    r = run(ig, _stop_cfg(rtol=0.9, min_it=5, skip=0), key=KEY)
    assert r.n_it_used == 5, r
    # and never before skip+1 regardless of min_it: the combined sdev is
    # inf while no iteration entered the combination
    r2 = run(ig, _stop_cfg(rtol=0.9, min_it=2, skip=6), key=KEY)
    assert r2.n_it_used == 7, r2


def test_inert_policy_is_the_fixed_loop():
    ig = igs.make_cosine(dim=2)
    plan = E.make_plan(ig, _stop_cfg(rtol=0.0, atol=0.0))
    assert plan.stop is None
    r = run(ig, _stop_cfg(rtol=0.0), key=KEY)
    assert r.n_it_used == KW["max_it"]
    assert r.mean == run(ig, VegasConfig(**KW), key=KEY).mean


def test_atol_stop_criterion():
    """atol is an absolute sdev target: combines as max(rtol|mean|, atol)."""
    ig = igs.make_cosine(dim=3)
    fixed = run(ig, VegasConfig(**KW), key=KEY)
    # an atol between the 3rd and final combined sdev stops mid-run
    atol = float(fixed.sdev) * 3.0
    r = run(ig, _stop_cfg(atol=atol, min_it=2), key=KEY)
    assert 2 <= r.n_it_used < KW["max_it"], r
    assert r.sdev <= atol


# --- batched per-scenario masks ----------------------------------------------

SIGMAS = [0.4, 0.25, 0.05, 0.003]
STOP = E.StopPolicy(rtol=2e-4, min_it=3)
BKEY = jax.random.PRNGKey(11)
BKW = dict(neval=8_000, max_it=8, skip=2, ninc=32, chunk=2048)


def test_batched_stop_masks_match_serial_exactly():
    """ISSUE 5 acceptance: a B=4 family under a loose rtol executes fewer
    effective iterations than max_it for some scenarios (per-scenario
    n_it_used), stragglers run the full loop, and the vmapped mask
    semantics reproduce the serial per-scenario trip counts EXACTLY."""
    fam = make_hetero_gaussian(SIGMAS)
    cfg = VegasConfig(execution=E.ExecutionConfig(stop=STOP), **BKW)
    batched = run_batch(fam, cfg, key=BKEY)
    serial = run_serial(fam, cfg, key=BKEY)

    np.testing.assert_array_equal(batched.n_it_used,
                                  [r.n_it_used for r in serial])
    assert batched.n_it_used.min() < BKW["max_it"], batched.n_it_used
    assert batched.n_it_used.max() == BKW["max_it"], batched.n_it_used
    # heterogeneous by construction: broad scenarios stop first
    assert (np.diff(batched.n_it_used) >= 0).all(), batched.n_it_used
    # estimates stay correct for every scenario, stopped or not
    pulls = (batched.mean - fam.targets) / batched.sdev
    assert (np.abs(pulls) < 5).all(), pulls


def test_batched_non_stopped_scenarios_match_fixed_loop_bitwise():
    """Scenarios whose mask never triggered ran the identical program as
    the fixed loop — bitwise, per ISSUE 5 ('matching the fixed-loop
    estimates for scenarios that don't stop'); stopped scenarios match on
    their executed prefix."""
    fam = make_hetero_gaussian(SIGMAS)
    cfg = VegasConfig(execution=E.ExecutionConfig(stop=STOP), **BKW)
    stopped = run_batch(fam, cfg, key=BKEY)
    fixed = run_batch(fam, VegasConfig(**BKW), key=BKEY)

    for b in range(len(SIGMAS)):
        n = int(stopped.n_it_used[b])
        np.testing.assert_array_equal(
            np.asarray(stopped.states.results[b][:n]),
            np.asarray(fixed.states.results[b][:n]), err_msg=f"scenario {b}")
        if n == BKW["max_it"]:
            assert stopped.mean[b] == fixed.mean[b], b
            np.testing.assert_array_equal(
                np.asarray(stopped.states.edges[b]),
                np.asarray(fixed.states.edges[b]), err_msg=f"scenario {b}")


def test_batched_stop_is_deterministic():
    fam = make_hetero_gaussian(SIGMAS)
    cfg = VegasConfig(execution=E.ExecutionConfig(stop=STOP), **BKW)
    r1 = run_batch(fam, cfg, key=BKEY)
    r2 = run_batch(fam, cfg, key=BKEY)
    np.testing.assert_array_equal(r1.n_it_used, r2.n_it_used)
    np.testing.assert_array_equal(r1.mean, r2.mean)


# --- resume ------------------------------------------------------------------

def test_resume_from_checkpoint_preserves_stop_statistics():
    """Checkpoint a FIXED run early (stop + checkpoint is a PlanError, the
    supported flow is checkpoint-then-resume-under-stop), then resume with
    the stop policy: the running stop statistics are a pure function of the
    carried results buffer, so the resumed run must stop at the same
    iteration with the same answer as the never-interrupted stop run."""
    ig = igs.make_cosine(dim=3)
    scratch = run(ig, _stop_cfg(rtol=1e-4, min_it=2), key=KEY)
    assert 3 < scratch.n_it_used < KW["max_it"], scratch

    saved = {}
    run(ig, VegasConfig(**{**KW, "max_it": 3}), key=KEY,
        checkpoint_cb=lambda it, s: saved.__setitem__("state", s))
    resumed = run(ig, _stop_cfg(rtol=1e-4, min_it=2), key=KEY,
                  state=saved["state"])
    assert resumed.n_it_used == scratch.n_it_used
    assert resumed.mean == pytest.approx(scratch.mean, rel=1e-6)
    assert resumed.sdev == pytest.approx(scratch.sdev, rel=1e-6)


def test_resume_already_converged_runs_zero_iterations():
    ig = igs.make_cosine(dim=3)
    done = run(ig, _stop_cfg(rtol=1e-4), key=KEY)
    again = run(ig, _stop_cfg(rtol=1e-4), key=KEY, state=done.state)
    assert again.n_it_used == done.n_it_used  # no extra iterations ran
    assert again.mean == done.mean


# --- plan validation + executor guards ---------------------------------------

def test_plan_rejects_stop_with_checkpoint():
    ig = igs.make_cosine(dim=2)
    ex = E.ExecutionConfig(stop=E.StopPolicy(rtol=0.01),
                           checkpoint=E.CheckpointPolicy(directory="/tmp/x"))
    with pytest.raises(E.PlanError, match="stop \\+ checkpoint"):
        E.make_plan(ig, VegasConfig(**KW), execution=ex)


def test_plan_rejects_negative_and_unreachable_stop():
    ig = igs.make_cosine(dim=2)
    with pytest.raises(E.PlanError, match="non-negative"):
        E.make_plan(ig, VegasConfig(**KW),
                    execution=E.ExecutionConfig(stop=E.StopPolicy(rtol=-1.0)))
    with pytest.raises(E.PlanError, match="min_it"):
        E.make_plan(ig, VegasConfig(**KW), execution=E.ExecutionConfig(
            stop=E.StopPolicy(rtol=0.01, min_it=KW["max_it"])))


def test_plan_rejects_stop_on_backend_without_capability():
    from repro.engine import backends as B
    ig = igs.make_cosine(dim=2)
    ref = B.get("ref")
    B.register(dataclasses.replace(
        ref, name="nostop",
        capabilities=ref.capabilities - {B.EARLY_STOP}))
    try:
        with pytest.raises(E.PlanError, match="early-stop"):
            E.make_plan(ig, VegasConfig(**KW), execution=E.ExecutionConfig(
                backend="nostop", stop=E.StopPolicy(rtol=0.01)))
    finally:
        del B._REGISTRY["nostop"]


def test_executor_rejects_legacy_checkpoint_cb_with_stop():
    ig = igs.make_cosine(dim=2)
    with pytest.raises(ValueError, match="checkpoint_cb"):
        run(ig, _stop_cfg(rtol=0.01), key=KEY,
            checkpoint_cb=lambda it, s: None)


def test_plan_describe_names_the_stop_axis():
    ig = igs.make_cosine(dim=2)
    plan = E.make_plan(ig, _stop_cfg(rtol=0.01, atol=1e-6, min_it=3))
    text = plan.describe()
    assert "while_loop" in text and "rtol=0.01" in text
    assert "stop[" in _stop_cfg(rtol=0.01).execution.describe()


# --- combine_results sentinel contract (ISSUE 5 satellite) -------------------

def _manual_combine(rows, skip, n_done):
    use = [i for i in range(len(rows))
           if skip <= i < n_done and np.isfinite(rows[i][1]) and rows[i][1] > 0]
    if not use:
        return 0.0, np.inf, 0.0, 0
    wts = {i: 1.0 / rows[i][1] for i in use}
    wsum = sum(wts.values())
    mean = sum(wts[i] * rows[i][0] for i in use) / wsum
    chi2 = sum(wts[i] * (rows[i][0] - mean) ** 2 for i in use)
    return mean, math.sqrt(1.0 / wsum), chi2 / max(len(use) - 1, 1), len(use)


def test_combine_results_ignores_inf_sentinels_for_every_n_done():
    """The fixed-shape buffer contract: for EVERY n_done < max_it the
    summary stats must ignore the unfilled (0, inf) sentinel slots — both
    through the isfinite guard and the idx < n_done mask."""
    max_it, skip = 8, 2
    rng = np.random.default_rng(3)
    rows = np.stack([rng.normal(1.0, 0.01, max_it).astype(np.float32),
                     rng.uniform(1e-4, 2e-4, max_it).astype(np.float32)], 1)
    for n_done in range(max_it + 1):
        buf = rows.copy()
        buf[n_done:, 0] = 0.0
        buf[n_done:, 1] = np.inf          # the init_state sentinel
        got = core.combine_results(jnp.asarray(buf), skip, n_done)
        want = _manual_combine(rows.tolist(), skip, n_done)
        for g, w in zip(got, want):
            assert float(g) == pytest.approx(w, rel=1e-5, abs=1e-12), (
                n_done, got, want)


def test_combine_results_masks_finite_garbage_past_n_done():
    """Even FINITE garbage past n_done must not leak in: the idx < n_done
    mask is load-bearing on its own, not just via the inf sentinels."""
    max_it, skip, n_done = 6, 1, 4
    rng = np.random.default_rng(5)
    rows = np.stack([rng.normal(1.0, 0.01, max_it).astype(np.float32),
                     rng.uniform(1e-4, 2e-4, max_it).astype(np.float32)], 1)
    garbage = rows.copy()
    garbage[n_done:] = [[777.0, 1e-9]] * (max_it - n_done)  # huge weight
    got = core.combine_results(jnp.asarray(garbage), skip, n_done)
    clean = core.combine_results(jnp.asarray(rows), skip, n_done)
    for g, c in zip(got, clean):
        assert float(g) == float(c), (got, clean)


def test_vegas_result_prefix_fields_exclude_sentinels():
    """RunResult consumers: iter_means/iter_sdevs are sliced to n_it_used,
    so no inf sentinel reaches the user-facing arrays of a stopped run."""
    ig = igs.make_cosine(dim=3)
    r = run(ig, _stop_cfg(rtol=0.02), key=KEY)
    assert r.iter_means.shape == (r.n_it_used,)
    assert r.iter_sdevs.shape == (r.n_it_used,)
    assert np.isfinite(np.asarray(r.iter_sdevs)).all()
    assert np.isfinite(r.mean) and np.isfinite(r.sdev)


# --- time-budget iteration caps (§12) ----------------------------------------

def test_it_cap_truncates_single_run_bitwise():
    """A capped run is the fixed run stopped early: the executed prefix is
    bit-identical, the slots past the cap keep their init sentinels."""
    ig = igs.make_cosine(dim=3)
    plan = E.make_plan(ig, VegasConfig(**KW))
    full = E.execute(plan, key=KEY)
    capped = E.execute(plan, key=KEY, it_caps=3)
    assert capped.n_it_used == 3
    np.testing.assert_array_equal(np.asarray(capped.state.results[:3]),
                                  np.asarray(full.state.results[:3]))
    np.testing.assert_array_equal(
        np.asarray(capped.state.results[3:, 1]),
        np.full(KW["max_it"] - 3, np.inf, np.float32))


def test_it_cap_is_a_hard_ceiling_over_min_it():
    """A spent budget stops the run even where the stop policy's min_it
    would rather keep adapting."""
    ig = igs.make_cosine(dim=2)
    plan = E.make_plan(ig, _stop_cfg(rtol=1e-6, min_it=5))
    r = E.execute(plan, key=KEY, it_caps=2)
    assert r.n_it_used == 2


def test_it_cap_above_max_it_is_inert():
    ig = igs.make_cosine(dim=2)
    plan = E.make_plan(ig, VegasConfig(**KW))
    r = E.execute(plan, key=KEY, it_caps=KW["max_it"] + 50)
    assert r.n_it_used == KW["max_it"]
    np.testing.assert_array_equal(np.asarray(r.state.results),
                                  np.asarray(E.execute(plan,
                                                       key=KEY).state.results))


def test_batched_per_scenario_caps():
    """Each lane gets its own budget: per-scenario caps ride the vmapped
    while_loop carry, and every executed prefix matches the uncapped run
    bitwise."""
    fam = make_hetero_gaussian(SIGMAS)
    cfg = VegasConfig(execution=E.ExecutionConfig(batch="vmap"), **BKW)
    plan = E.make_plan(fam, cfg)
    caps = np.array([2, 5, 3, BKW["max_it"]], np.int32)
    res = E.execute(plan, key=BKEY, it_caps=caps)
    np.testing.assert_array_equal(res.n_it_used, caps)
    full = E.execute(plan, key=BKEY)
    for b, c in enumerate(caps):
        np.testing.assert_array_equal(
            np.asarray(res.states.results[b, :c]),
            np.asarray(full.states.results[b, :c]))


def test_batched_scalar_cap_broadcasts():
    fam = make_hetero_gaussian(SIGMAS)
    cfg = VegasConfig(execution=E.ExecutionConfig(batch="vmap"), **BKW)
    res = E.execute(E.make_plan(fam, cfg), key=BKEY, it_caps=3)
    np.testing.assert_array_equal(res.n_it_used, [3, 3, 3, 3])


def test_caps_compose_with_stop_policy_per_scenario():
    """Stop masks and budget caps are independent per-lane exits: a lane
    stops at whichever bites first."""
    fam = make_hetero_gaussian(SIGMAS)
    cfg = VegasConfig(execution=E.ExecutionConfig(stop=STOP), **BKW)
    plan = E.make_plan(fam, cfg)
    uncapped = E.execute(plan, key=BKEY)
    caps = np.maximum(np.asarray(uncapped.n_it_used) - 1, 1).astype(np.int32)
    res = E.execute(plan, key=BKEY, it_caps=caps)
    np.testing.assert_array_equal(res.n_it_used,
                                  np.minimum(uncapped.n_it_used, caps))


def test_single_run_rejects_vector_cap():
    ig = igs.make_cosine(dim=2)
    plan = E.make_plan(ig, VegasConfig(**KW))
    with pytest.raises(ValueError, match="scalar it_cap"):
        E.execute(plan, key=KEY, it_caps=np.array([2, 3]))


def test_batched_rejects_wrong_cap_shape():
    fam = make_hetero_gaussian(SIGMAS)
    cfg = VegasConfig(execution=E.ExecutionConfig(batch="vmap"), **BKW)
    with pytest.raises(ValueError, match="it_caps shape"):
        E.execute(E.make_plan(fam, cfg), key=BKEY,
                  it_caps=np.array([2, 3], np.int32))
