"""Subprocess worker for multi-device tests. Run with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the parent test).
Prints machine-readable results; exits nonzero on failure."""

import os

from repro.launch import env as launch_env

# Before jax initializes its backends: 8 host devices + pinned CPU platform
# (launch.env is the one place for these process-level knobs).
launch_env.set_host_device_count(8)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402

import repro.engine as E  # noqa: E402
from repro.core import integrator as I  # noqa: E402
from repro.core import fill as F  # noqa: E402
from repro.core.integrands import make_cosine  # noqa: E402
from repro.dist import sharded_fill as SF  # noqa: E402
from repro.dist import checkpoint as CK  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402


def mesh_of(shape, names):
    # launch.mesh.make_mesh: Auto axis types where the jax version has them.
    return make_mesh(shape, names)


def main():
    assert jax.device_count() == 8, jax.device_count()
    ig = make_cosine(dim=4)
    cfg = I.VegasConfig(neval=40_000, max_it=6, skip=2, ninc=64, chunk=2048)
    rc = cfg.resolve(ig.dim)
    key = jax.random.PRNGKey(0)

    # --- 1) device-count invariance of the fill --------------------------
    st = I.init_state(ig, rc, key)
    key_it = jax.random.fold_in(st.key, st.it)
    plain = F.fill_reference(st.edges, st.n_h, key_it, ig, nstrat=rc.nstrat,
                             n_cap=rc.n_cap, chunk=rc.chunk)
    mesh8 = mesh_of((8,), ("data",))
    fill8 = SF.make_sharded_fill(mesh8, ("data",), rc)
    shard8 = fill8(st.edges, st.n_h, key_it, ig)
    np.testing.assert_allclose(shard8.map_sums, plain.map_sums, rtol=2e-5)
    np.testing.assert_allclose(shard8.cube_s1, plain.cube_s1, rtol=2e-5, atol=1e-7)
    print("CHECK fill_invariance OK")

    # --- 2) 2D mesh (data x model) sharding over both axes ---------------
    mesh2d = mesh_of((4, 2), ("data", "model"))
    fill2d = SF.make_sharded_fill(mesh2d, ("data", "model"), rc)
    shard2d = fill2d(st.edges, st.n_h, key_it, ig)
    np.testing.assert_allclose(shard2d.map_sums, plain.map_sums, rtol=2e-5)
    print("CHECK mesh2d OK")

    # --- 3) full runs agree across meshes (reduction-order tolerance) ----
    r1 = I.run(ig, cfg, key=key)
    r8 = I.run(ig, cfg, key=key, fill_fn=fill8)
    assert abs(r1.mean - r8.mean) < 5e-5 * abs(r1.mean), (r1.mean, r8.mean)
    print(f"CHECK run_equiv OK mean1={r1.mean:.8g} mean8={r8.mean:.8g}")

    # --- 4) elastic restart: checkpoint on 2 devices, resume on 8 --------
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        mgr = CK.CheckpointManager(td, keep=2)
        mesh2 = mesh_of((2,), ("data",))
        fill2 = SF.make_sharded_fill(mesh2, ("data",), rc)
        cfg_half = I.VegasConfig(neval=40_000, max_it=3, skip=2, ninc=64,
                                 chunk=2048)
        half = I.run(ig, cfg_half, key=key, fill_fn=fill2,
                     checkpoint_cb=lambda it, s: mgr.save(it, s))
        # Restore against a freshly-initialized template: only the tree
        # STRUCTURE matters, shapes come from the file.
        like = I.init_state(ig, cfg.resolve(ig.dim), key)
        restored, step, _ = mgr.restore_latest(like)
        resumed = I.run(ig, cfg, key=key, state=restored, fill_fn=fill8)
        straight = I.run(ig, cfg, key=key, fill_fn=fill8)
        assert abs(resumed.mean - straight.mean) < 5e-5 * abs(straight.mean), \
            (resumed.mean, straight.mean)
        print(f"CHECK elastic OK resumed={resumed.mean:.8g} straight={straight.mean:.8g}")

    # --- 5b) pallas fused backend: device-count invariance ---------------
    # The fused kernel (in-kernel RNG + in-kernel cube accumulation) shares
    # the chunk-keyed stream bit-for-bit with fill_reference, so the sharded
    # fused fill must agree with BOTH the unsharded fused fill and the plain
    # reference fill at the reduction-order tolerance.
    cfg_p = I.VegasConfig(neval=20_000, max_it=4, skip=1, ninc=64, chunk=2048,
                          execution=E.ExecutionConfig(backend="pallas-fused",
                                                      interpret=True))
    rc_p = cfg_p.resolve(ig.dim)
    st_p = I.init_state(ig, rc_p, key)
    key_p = jax.random.fold_in(st_p.key, st_p.it)
    plain_ref = F.fill_reference(st_p.edges, st_p.n_h, key_p, ig,
                                 nstrat=rc_p.nstrat, n_cap=rc_p.n_cap,
                                 chunk=rc_p.chunk)
    plain_fused = F.fill_pallas(st_p.edges, st_p.n_h, key_p, ig,
                                nstrat=rc_p.nstrat, n_cap=rc_p.n_cap,
                                chunk=rc_p.chunk, interpret=True,
                                fused_cubes=True, kahan=True)
    fused8 = SF.make_sharded_fill(mesh8, ("data",), rc_p)  # backend from cfg
    shard_fused = fused8(st_p.edges, st_p.n_h, key_p, ig)
    for got, want, tag in [(shard_fused, plain_fused, "sharded-vs-fused"),
                           (shard_fused, plain_ref, "sharded-vs-ref")]:
        np.testing.assert_allclose(got.map_sums, want.map_sums, rtol=2e-5,
                                   err_msg=tag)
        np.testing.assert_allclose(got.cube_s1, want.cube_s1, rtol=2e-5,
                                   atol=1e-7, err_msg=tag)
    print("CHECK pallas_fused_invariance OK")

    # --- 5) straggler re-dispatch: shard k recomputed locally ------------
    total = None
    for k8 in range(8):
        part = SF.recompute_shard(st.edges, st.n_h, key_it, ig, rc, k8, 8)
        total = part if total is None else total + part
    np.testing.assert_allclose(total.map_sums, plain.map_sums, rtol=2e-5)
    np.testing.assert_allclose(total.cube_s1, plain.cube_s1, rtol=2e-5, atol=1e-7)
    print("CHECK straggler OK")

    # --- 6) engine: sharded x batched in ONE jitted program --------------
    # ISSUE 4 acceptance: a B=4 integrand family on 8 devices with the
    # pallas-fused backend executes through repro.engine as one program
    # (iteration_step traced exactly once), and every scenario matches its
    # serial single-scenario baseline at the tests/test_batch.py tolerance
    # (3 combined sigma).
    from repro.batch.engine import run_serial
    from repro.batch.family import make_gaussian_family
    fam = make_gaussian_family(np.linspace(0.2, 0.8, 4), dim=2)
    cfg_b = I.VegasConfig(neval=16_000, max_it=6, skip=2, ninc=32, chunk=2048)
    ex = E.ExecutionConfig(backend="pallas-fused", interpret=True,
                           mesh=mesh8, shard_axes=("data",))
    plan = E.make_plan(fam, cfg_b, execution=ex)
    assert plan.batched and plan.n_shards == 8, plan.describe()

    calls = {"trace": 0}
    real_step = I.iteration_step

    def counting_step(*a, **k):
        calls["trace"] += 1
        return real_step(*a, **k)

    I.iteration_step = counting_step
    try:
        res = E.execute(plan, key=jax.random.PRNGKey(42))
    finally:
        I.iteration_step = real_step
    assert calls["trace"] == 1, calls  # ONE jitted program for B x D

    serial = run_serial(fam, cfg_b.with_execution(
        E.ExecutionConfig(backend="pallas-fused", interpret=True)),
        key=jax.random.PRNGKey(42))
    for b in range(4):
        comb = float(np.hypot(res.sdev[b], serial[b].sdev))
        gap = abs(float(res.mean[b]) - serial[b].mean)
        assert gap < 3 * comb, (b, float(res.mean[b]), serial[b].mean, comb)
    pulls = (res.mean - fam.targets) / res.sdev
    assert (np.abs(pulls) < 5).all(), pulls
    print("CHECK engine_sharded_batched OK")

    # plan validation rejects unsupported combinations loudly (PlanError at
    # plan time, never a tracer failure)
    for bad in (E.ExecutionConfig(backend="pallas-fused",
                                  shard_axes=("data",)),       # axes, no mesh
                E.ExecutionConfig(backend="cuda"),             # unknown name
                E.ExecutionConfig(mesh=mesh8, tile=128)):      # knob on ref
        try:
            E.make_plan(fam, cfg_b, execution=bad)
        except E.PlanError:
            pass
        else:
            raise AssertionError(f"PlanError expected for {bad}")
    print("CHECK engine_plan_validation OK")

    # --- 7) early stopping under sharding (ISSUE 5, DESIGN.md §10) --------
    # The sharded batched while_loop pmin-agrees its continue decision over
    # the mesh (make_stop_sync): per-scenario n_it_used on 8 devices must
    # equal the unsharded batched run's exactly, and every shard returns
    # the same replicated answer (shard_map out_specs enforce it).  The
    # family mixes per-scenario Gaussian WIDTHS so the trip counts are
    # heterogeneous — some lanes converge and mask off while others run to
    # max_it — which is the only regime where the per-lane mask semantics
    # and the cross-shard agreement actually carry weight.
    import math as _math

    from repro.batch.family import IntegrandFamily

    def _hetero(sigmas, dim=2, mu=0.5):
        def fn(sigma, x):
            norm = (2.0 * _math.pi * sigma**2) ** (-dim / 2.0)
            return norm * jax.numpy.exp(
                -jax.numpy.sum((x - mu) ** 2, axis=-1) / (2.0 * sigma**2))
        return IntegrandFamily("hetero", dim, fn, (0.0,) * dim,
                               (1.0,) * dim,
                               jax.numpy.asarray(sigmas, jax.numpy.float32))

    fam_h = _hetero([0.4, 0.25, 0.05, 0.003])
    cfg_h = I.VegasConfig(neval=16_000, max_it=8, skip=2, ninc=32,
                          chunk=2048)
    stopex = E.StopPolicy(rtol=2e-4, min_it=3)
    ex_stop8 = E.ExecutionConfig(mesh=mesh8, shard_axes=("data",),
                                 stop=stopex)
    res8 = E.execute(E.make_plan(fam_h, cfg_h, execution=ex_stop8),
                     key=jax.random.PRNGKey(42))
    res1 = E.execute(E.make_plan(fam_h, cfg_h,
                                 execution=E.ExecutionConfig(stop=stopex)),
                     key=jax.random.PRNGKey(42))
    assert np.array_equal(res8.n_it_used, res1.n_it_used), \
        (res8.n_it_used, res1.n_it_used)
    # heterogeneous by construction: the check is vacuous unless some lanes
    # stopped early AND some ran the full loop
    assert res8.n_it_used.min() < cfg_h.max_it <= res8.n_it_used.max(), \
        res8.n_it_used
    np.testing.assert_allclose(res8.mean, res1.mean, rtol=5e-5)
    print(f"CHECK sharded_early_stop OK n_it_used={res8.n_it_used.tolist()}")

    print("ALL_OK")


if __name__ == "__main__":
    main()
