"""Batched multi-integrand engine: batched-vs-serial agreement, single-program
execution, and the warm-start map cache (ISSUE 2 acceptance criteria)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.batch import MapCache, run_batch, run_serial
from repro.batch.family import (make_asian_family, make_gaussian_family,
                                make_ridge_family)
from repro.core import VegasConfig

FAST = VegasConfig(neval=16_000, max_it=8, skip=3, ninc=64, chunk=4096)


def test_batched_matches_serial_within_3_sigma_b8():
    """Acceptance: B=8 batched run matches 8 serial ``run()`` calls within 3
    combined sigma per scenario (same per-scenario keys)."""
    fam = make_gaussian_family(np.linspace(0.15, 0.85, 8))
    key = jax.random.PRNGKey(42)
    batched = run_batch(fam, FAST, key=key)
    serial = run_serial(fam, FAST, key=key)
    for b in range(8):
        comb = float(np.hypot(batched.sdev[b], serial[b].sdev))
        gap = abs(float(batched.mean[b]) - serial[b].mean)
        assert gap < 3 * comb, (b, batched.mean[b], serial[b].mean, comb)


def test_batched_scenarios_converge_to_targets():
    fam = make_gaussian_family(np.linspace(0.25, 0.75, 4))
    res = run_batch(fam, FAST, key=jax.random.PRNGKey(7))
    pulls = (res.mean - fam.targets) / res.sdev
    assert (np.abs(pulls) < 5).all(), pulls
    assert (res.n_used == FAST.max_it - FAST.skip).all()


def test_asian_family_matches_closed_form():
    fam = make_asian_family(np.linspace(90.0, 110.0, 4), n_steps=8,
                            geometric=True)
    cfg = VegasConfig(neval=30_000, max_it=8, skip=3, ninc=128, chunk=8192)
    res = run_batch(fam, cfg, key=jax.random.PRNGKey(3))
    pulls = (res.mean - fam.targets) / res.sdev
    assert (np.abs(pulls) < 5).all(), pulls


def test_ridge_family_orientations_have_targets():
    dirs = np.array([[1.0, 1.0, 1.0], [0.6, 0.8, 1.0]])
    fam = make_ridge_family(dirs, dim=3, n_peaks=20)
    cfg = VegasConfig(neval=30_000, max_it=8, skip=3, ninc=64, chunk=8192)
    res = run_batch(fam, cfg, key=jax.random.PRNGKey(9))
    pulls = (res.mean - fam.targets) / res.sdev
    assert (np.abs(pulls) < 5).all(), pulls


def test_batched_run_is_single_jitted_program(monkeypatch):
    """No per-iteration host sync: the engine must hand XLA ONE program —
    ``iteration_step`` is traced (constant-folded into the loop), never
    executed eagerly, and the program runs once."""
    from repro.core import integrator as core

    calls = {"trace": 0}
    real_step = core.iteration_step

    def counting_step(*a, **k):
        calls["trace"] += 1
        return real_step(*a, **k)

    monkeypatch.setattr(core, "iteration_step", counting_step)
    fam = make_gaussian_family(np.array([0.3, 0.7]))
    run_batch(fam, FAST, key=jax.random.PRNGKey(0))
    # Traced exactly once (inside fori_loop tracing), not max_it times.
    assert calls["trace"] == 1, calls


def test_family_instance_matches_batched_fn():
    fam = make_gaussian_family(np.array([0.3, 0.6]))
    x = jax.random.uniform(jax.random.PRNGKey(0), (32, fam.dim))
    for b in range(2):
        ig = fam.instance(b)
        np.testing.assert_allclose(
            ig(x), fam.fn(jax.tree.map(lambda l: l[b], fam.params), x),
            rtol=1e-6)
        assert ig.target == pytest.approx(float(fam.targets[b]))


def test_map_cache_roundtrip_and_warm_start(tmp_path):
    fam = make_gaussian_family(np.array([0.3, 0.7]))
    path = str(tmp_path / "maps.npz")
    cache = MapCache(path)
    r1 = run_batch(fam, FAST, key=jax.random.PRNGKey(1), cache=cache)
    assert not r1.warm_started
    assert len(cache) == 1

    # Fresh cache object from disk: the entry persists and warm-starts.
    cache2 = MapCache(path)
    assert len(cache2) == 1
    r2 = run_batch(fam, FAST, key=jax.random.PRNGKey(2), cache=cache2)
    assert r2.warm_started
    pulls = (r2.mean - fam.targets) / r2.sdev
    assert (np.abs(pulls) < 5).all()

    # Different config (ninc) must miss — geometry is part of the key.
    other = VegasConfig(neval=16_000, max_it=8, skip=3, ninc=32, chunk=4096)
    assert cache2.get(fam, other.resolve(fam.dim)) is None


def test_warm_start_edges_are_the_converged_maps():
    fam = make_gaussian_family(np.array([0.4, 0.6]))
    cache = MapCache()
    r1 = run_batch(fam, FAST, key=jax.random.PRNGKey(1), cache=cache)
    stored = cache.get(fam, FAST.resolve(fam.dim))
    np.testing.assert_allclose(np.asarray(stored),
                               np.asarray(r1.states.edges), rtol=1e-6)
    assert (jnp.diff(stored, axis=-1) > 0).all()  # still a valid map


def test_map_cache_concurrent_writers_merge(tmp_path):
    """Two writers sharing one cache path (a service + a CLI sweep) must
    not drop each other's entries: each flush reloads the on-disk state
    and overlays only its own dirty keys (the lost-update regression)."""
    path = str(tmp_path / "shared.npz")
    fam_a = make_gaussian_family(np.array([0.3, 0.7]))
    fam_b = make_gaussian_family(np.array([0.2, 0.5, 0.8]))  # other key (B)
    # Both writers snapshot the (absent) file BEFORE either flushes — the
    # exact interleaving that lost writer A's entry under the old
    # rewrite-from-init-snapshot flush.
    writer_a = MapCache(path)
    writer_b = MapCache(path)
    run_batch(fam_a, FAST, key=jax.random.PRNGKey(1), cache=writer_a)
    run_batch(fam_b, FAST, key=jax.random.PRNGKey(2), cache=writer_b)

    merged = MapCache(path)
    assert len(merged) == 2
    rcfg = FAST.resolve(fam_a.dim)
    assert merged.get(fam_a, rcfg) is not None
    assert merged.get(fam_b, FAST.resolve(fam_b.dim)) is not None

    # And writer_b itself picked up A's entry at flush time (merge, not
    # blind overwrite).
    assert writer_b.get(fam_a, rcfg) is not None


def test_map_cache_flush_overwrites_own_keys_only(tmp_path):
    """A writer's flush updates the keys it wrote and leaves a concurrent
    writer's FRESHER value of an untouched key alone (its own init
    snapshot of that key is stale, not authoritative)."""
    import dataclasses as _dc

    path = str(tmp_path / "shared2.npz")
    fam = make_gaussian_family(np.array([0.3, 0.7]))
    rcfg = FAST.resolve(fam.dim)
    shape = (fam.batch_size, fam.dim, rcfg.ninc + 1)

    seed = MapCache(path)
    seed.put(fam, rcfg, np.full(shape, 1.0))
    stale = MapCache(path)          # snapshots value 1.0
    fresh = MapCache(path)
    fresh.put(fam, rcfg, np.full(shape, 2.0))  # concurrent update

    other = _dc.replace(FAST, ninc=32).resolve(fam.dim)
    stale.put(fam, other, np.full((fam.batch_size, fam.dim, 33), 3.0))

    disk = MapCache(path)
    # stale's flush wrote its own new key but did NOT roll fam@FAST back
    # to its 1.0 snapshot.
    assert float(np.asarray(disk.get(fam, rcfg))[0, 0, 0]) == 2.0
    assert disk.get(fam, other) is not None


def test_map_cache_key_pins_dtype():
    """f64-adapted edges are not an f32 map: dtype is part of the key, so
    a run under the other accumulation dtype misses instead of silently
    casting."""
    import dataclasses as _dc

    fam = make_gaussian_family(np.array([0.3, 0.7]))
    rcfg32 = FAST.resolve(fam.dim)
    rcfg64 = _dc.replace(FAST, dtype="float64").resolve(fam.dim)
    cache = MapCache()
    cache.put(fam, rcfg32, np.zeros((fam.batch_size, fam.dim,
                                     rcfg32.ninc + 1), np.float32))
    assert cache.get(fam, rcfg64) is None
    assert cache.get(fam, rcfg32) is not None
