"""Property-based tests (hypothesis) for the frozen-map change of variables
(§11): `rescale_edges` must be a positive-jacobian, endpoint-exact affine
remap for ANY monotone map and bounds, and the bounds-derivative of a
constant integrand must obey the exact product-rule identity."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Property tests need hypothesis (requirements-dev.txt); skip the module —
# don't fail collection — where it isn't installed.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import VegasConfig  # noqa: E402
from repro.grad import differentiable, rescale_edges, score_surrogate  # noqa: E402

TINY = VegasConfig(neval=1_000, max_it=2, ninc=16, chunk=512)


def _edges(dim, ninc, seed, lo, hi):
    """A random strictly-monotone map on [lo, hi] per dim (what adaptation
    produces: sorted interior knots, pinned endpoints)."""
    rng = np.random.default_rng(seed)
    # Strictly positive interval widths, normalized so t spans [0, 1] with
    # EXACT endpoints (t[:, 0] == 0, t[:, -1] == 1 by construction).
    widths = rng.uniform(0.1, 1.0, size=(dim, ninc))
    t = np.concatenate([np.zeros((dim, 1)), np.cumsum(widths, axis=1)], 1)
    t = t / t[:, -1:]
    return jnp.asarray(lo[:, None] + (hi - lo)[:, None] * t, jnp.float32)


bounds_st = st.tuples(
    st.integers(1, 4),                       # dim
    st.integers(0, 10_000),                  # map seed
    st.floats(-2.0, 1.0),                    # lower anchor
    st.floats(0.1, 3.0),                     # width
)


@given(bounds_st, st.floats(-1.0, 2.0), st.floats(0.2, 2.5))
@settings(max_examples=40, deadline=None)
def test_rescale_edges_is_positive_jacobian_remap(spec, new_lo, new_w):
    dim, seed, lo, w = spec
    l0 = np.full(dim, lo, np.float32)
    u0 = l0 + np.float32(w)
    edges0 = _edges(dim, 8, seed, l0, u0)
    lower = jnp.full((dim,), new_lo, jnp.float32)
    upper = lower + jnp.float32(new_w)

    out = np.asarray(rescale_edges(edges0, lower, upper))
    # Endpoints land EXACTLY on the requested bounds (the map integrates
    # over precisely the requested box)...
    np.testing.assert_allclose(out[:, 0], np.asarray(lower), atol=1e-6)
    np.testing.assert_allclose(out[:, -1], np.asarray(upper), atol=1e-6)
    # ... every interval keeps positive width (jacobian > 0 everywhere) ...
    assert np.all(np.diff(out, axis=1) > 0.0), out
    # ... and relative knot positions are preserved (affine, per dim).
    t_in = (np.asarray(edges0) - l0[:, None]) / (u0 - l0)[:, None]
    t_out = (out - np.asarray(lower)[:, None]) / np.asarray(upper - lower)[:, None]
    np.testing.assert_allclose(t_out, t_in, atol=2e-5)


@given(bounds_st)
@settings(max_examples=40, deadline=None)
def test_rescale_edges_identity_at_own_bounds(spec):
    dim, seed, lo, w = spec
    l0 = np.full(dim, lo, np.float32)
    u0 = l0 + np.float32(w)
    edges0 = _edges(dim, 8, seed, l0, u0)
    out = rescale_edges(edges0, jnp.asarray(l0), jnp.asarray(u0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(edges0),
                               rtol=1e-5, atol=1e-6)


@given(st.floats(0.5, 4.0), st.integers(0, 50),
       st.floats(0.2, 1.5), st.floats(0.3, 2.0))
@settings(max_examples=8, deadline=None)
def test_constant_integrand_bounds_derivative_exact(c, seed, w0, w1):
    """est(lower, upper) = c * prod(upper - lower) for a constant integrand
    whatever the (frozen) map — so d(est)/d(upper_j) == est / width_j and
    d(est)/d(lower_j) == -est / width_j EXACTLY (one full two-phase run per
    example: keep max_examples small)."""
    fn = lambda _p, x: jnp.full(x.shape[:-1], np.float32(c))
    est = differentiable(fn, 2, (0.0, 0.0), (w0, w1), TINY, name="const")
    key = jax.random.PRNGKey(seed)
    lower = jnp.zeros(2, jnp.float32)
    upper = jnp.asarray([w0, w1], jnp.float32)

    val, (gl, gu) = jax.value_and_grad(
        lambda l, u: est.pair(jnp.zeros(()), l, u, key)[0],
        argnums=(0, 1))(lower, upper)
    v = float(val)
    widths = np.asarray(upper - lower)
    assert math.isclose(v, c * widths.prod(), rel_tol=1e-4)
    np.testing.assert_allclose(np.asarray(gu), v / widths, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gl), -v / widths, rtol=1e-4)


@given(st.floats(1e-3, 1e3), st.floats(-2.0, 2.0))
@settings(max_examples=50, deadline=None)
def test_score_surrogate_value_and_tangent(f0, df):
    """value(surrogate) == f; tangent(surrogate) == f * d(log f) == df for
    any positive f — the score-function identity the mode rests on."""
    g = lambda t: score_surrogate(jnp.float32(f0) * (1.0 + t * np.float32(df)))
    v, tangent = jax.jvp(g, (jnp.float32(0.0),), (jnp.float32(1.0),))
    assert np.isclose(float(v), f0, rtol=1e-5)
    assert np.isclose(float(tangent), f0 * df, rtol=1e-4, atol=1e-6)
