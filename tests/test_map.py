"""Unit + property tests for the adaptive importance map."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Property tests need hypothesis (requirements-dev.txt); skip the module —
# not the whole collection — where it is absent.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import map as vmap_  # noqa: E402


def _random_edges(key, d, ninc, lo=-2.0, hi=3.0):
    w = jax.random.uniform(key, (d, ninc), minval=0.05, maxval=1.0)
    w = w / w.sum(1, keepdims=True) * (hi - lo)
    return jnp.concatenate([jnp.full((d, 1), lo), lo + jnp.cumsum(w, axis=1)], axis=1)


def test_uniform_edges_shape_and_bounds():
    e = vmap_.uniform_edges([0.0, -1.0], [1.0, 2.0], 16)
    assert e.shape == (2, 17)
    np.testing.assert_allclose(e[:, 0], [0.0, -1.0])
    np.testing.assert_allclose(e[:, -1], [1.0, 2.0], rtol=1e-6)
    assert (jnp.diff(e, axis=1) > 0).all()


def test_apply_map_uniform_is_identityish():
    # Uniform map on [0,1]: x == y and jac == 1.
    e = vmap_.uniform_edges([0.0], [1.0], 64)
    y = jnp.linspace(0.001, 0.999, 50)[:, None]
    x, jac, iy = vmap_.apply_map(e, y)
    np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(jac, jnp.ones(50), rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(d=st.integers(1, 6), ninc=st.integers(2, 64), seed=st.integers(0, 2**30))
def test_apply_map_jacobian_measures_volume(d, ninc, seed):
    """MC average of the Jacobian over uniform y equals the volume (the map
    is a change of variables: int_0^1 J dy = prod (b-a))."""
    key = jax.random.PRNGKey(seed)
    edges = _random_edges(jax.random.fold_in(key, 1), d, ninc)
    vol = float(jnp.prod(edges[:, -1] - edges[:, 0]))
    y = jax.random.uniform(jax.random.fold_in(key, 2), (4096, d))
    _, jac, _ = vmap_.apply_map(edges, y)
    est = float(jac.mean())
    sd = float(jac.std() / np.sqrt(y.shape[0]))
    assert abs(est - vol) < max(6 * sd, 1e-3 * abs(vol))


@settings(max_examples=25, deadline=None)
@given(d=st.integers(1, 4), ninc=st.integers(4, 64), seed=st.integers(0, 2**30),
       alpha=st.floats(0.1, 2.0))
def test_adapt_edges_preserves_bounds_and_monotonicity(d, ninc, seed, alpha):
    key = jax.random.PRNGKey(seed)
    edges = _random_edges(jax.random.fold_in(key, 1), d, ninc)
    sums = jax.random.uniform(jax.random.fold_in(key, 2), (d, ninc)) ** 4
    counts = jnp.ones((d, ninc)) * 7
    new = vmap_.adapt_edges(edges, sums, counts, alpha)
    assert new.shape == edges.shape
    np.testing.assert_allclose(new[:, 0], edges[:, 0], rtol=1e-6)
    np.testing.assert_allclose(new[:, -1], edges[:, -1], rtol=1e-6)
    assert (jnp.diff(new, axis=1) >= 0).all()
    assert jnp.isfinite(new).all()


def test_adapt_concentrates_on_peak():
    """After adapting on weights peaked in one region, interval widths there
    must shrink (more intervals near the peak = importance sampling)."""
    ninc = 64
    edges = vmap_.uniform_edges([0.0], [1.0], ninc)
    centers = (edges[0, :-1] + edges[0, 1:]) / 2
    sums = jnp.exp(-((centers - 0.3) ** 2) / (2 * 0.02**2))[None, :]
    counts = jnp.ones((1, ninc))
    new = edges
    for _ in range(5):
        new = vmap_.adapt_edges(new, sums, counts, alpha=1.0)
    widths = jnp.diff(new[0])
    near = widths[jnp.abs((new[0, :-1] + new[0, 1:]) / 2 - 0.3) < 0.05]
    far = widths[jnp.abs((new[0, :-1] + new[0, 1:]) / 2 - 0.3) > 0.2]
    assert near.mean() < far.mean() / 2  # clearly finer near the peak


def test_accumulate_map_weights_matches_numpy():
    key = jax.random.PRNGKey(0)
    n, d, ninc = 500, 3, 16
    iy = jax.random.randint(key, (n, d), 0, ninc)
    w2 = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    cnt = (jax.random.uniform(jax.random.fold_in(key, 2), (n,)) > 0.3).astype(jnp.float32)
    sums, counts = vmap_.accumulate_map_weights(iy, w2, cnt, ninc)
    sums_np = np.zeros((d, ninc)); counts_np = np.zeros((d, ninc))
    iy_n, w2_n, c_n = np.asarray(iy), np.asarray(w2), np.asarray(cnt)
    for e in range(n):
        for k in range(d):
            sums_np[k, iy_n[e, k]] += w2_n[e]
            counts_np[k, iy_n[e, k]] += c_n[e]
    np.testing.assert_allclose(sums, sums_np, rtol=2e-5)
    np.testing.assert_allclose(counts, counts_np, rtol=2e-5)
