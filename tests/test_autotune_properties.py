"""Hypothesis property suite for the plan autotuner (ISSUE 8, DESIGN.md §13).

Four invariants the acceptance criteria name:

  * every tile the tuner can consider divides the chunk AND fits the
    kernel's VMEM footprint model (validity delegated to `ops.valid_tiles`,
    the same oracle `_pick_tile` enforces);
  * `make_plan(autotune=True)` never raises PlanError on a (workload,
    backend) combination that succeeds with default knobs, and the chosen
    knobs survive an explicit re-plan unchanged;
  * predicted cost is monotone in ``neval`` (non-negative coefficients);
  * tuning is deterministic for a fixed table.

Skips cleanly where hypothesis is not installed (the minimal CI image).
"""

import dataclasses

import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import VegasConfig  # noqa: E402
from repro.core.integrands import make_cosine  # noqa: E402
from repro.engine import ExecutionConfig, available, make_plan  # noqa: E402
from repro.engine import autotune as at  # noqa: E402
from repro.kernels import ops  # noqa: E402

COMMON = settings(max_examples=30, deadline=None)


@COMMON
@given(chunk_pow=st.integers(6, 15), d=st.integers(1, 12),
       ninc=st.sampled_from([16, 64, 256, 1024]),
       cubes_pow=st.integers(0, 16))
def test_valid_tiles_divide_and_fit_vmem(chunk_pow, d, ninc, cubes_pow):
    chunk, n_cubes = 1 << chunk_pow, 1 << cubes_pow
    tiles = ops.valid_tiles(chunk, d, ninc, n_cubes)
    assert tiles == sorted(tiles)
    for t in tiles:
        assert chunk % t == 0
        assert ops.tile_footprint_bytes(t, d, ninc, n_cubes) <= 8 << 20
    # the static VMEM autotune picks from the same oracle (largest valid)
    if tiles:
        assert ops.autotune_tile(chunk, d, ninc, n_cubes) == tiles[-1]
    # ...and so does the tuner's candidate subset
    for cand in at._tile_candidates(chunk, d, ninc, n_cubes):
        assert cand is None or cand in tiles


@COMMON
@given(neval=st.integers(1_000, 200_000), dim=st.integers(1, 10),
       chunk_pow=st.integers(9, 16),
       backend=st.sampled_from(sorted(available())))
def test_autotune_never_rejects_where_defaults_succeed(neval, dim, chunk_pow,
                                                       backend):
    ig = make_cosine(dim=dim)
    kw = dict(neval=neval, max_it=4, ninc=64, chunk=1 << chunk_pow)
    baseline = make_plan(ig, VegasConfig(
        execution=ExecutionConfig(backend=backend), **kw))
    tuned = make_plan(ig, VegasConfig(
        execution=ExecutionConfig(backend=backend, autotune=True), **kw))
    assert tuned.tuned is not None
    assert tuned.backend.name == baseline.backend.name
    # chosen knobs survive an explicit re-plan bit-for-bit
    replan = make_plan(ig, VegasConfig(
        execution=tuned.execution, **{**kw, "chunk": tuned.cfg.chunk}))
    assert replan.cfg.chunk == tuned.cfg.chunk
    assert replan.cfg.n_cap == tuned.cfg.n_cap
    assert replan.execution.tile == tuned.execution.tile


@COMMON
@given(neval_a=st.integers(1_000, 500_000), factor=st.integers(2, 8),
       dim=st.integers(1, 10),
       key=st.sampled_from(sorted(at.BUILTIN_CLASSES)))
def test_prediction_monotone_in_neval(neval_a, factor, dim, key):
    coeffs = at.BUILTIN_TABLE.coeffs(key)
    cfg = VegasConfig(max_it=6, chunk=4_096)
    lo = at.predict_run_s(coeffs,
                          dataclasses.replace(cfg, neval=neval_a).resolve(dim))
    hi = at.predict_run_s(coeffs, dataclasses.replace(
        cfg, neval=neval_a * factor).resolve(dim))
    assert hi >= lo


@COMMON
@given(neval=st.integers(1_000, 200_000), dim=st.integers(1, 10),
       chunk_pow=st.integers(9, 16))
def test_tune_deterministic(neval, dim, chunk_pow):
    ig = make_cosine(dim=dim)
    cfg = VegasConfig(neval=neval, max_it=4, ninc=64, chunk=1 << chunk_pow,
                      execution=ExecutionConfig(autotune=True))
    a, ra = at.tune(ig, cfg, table=at.BUILTIN_TABLE)
    b, rb = at.tune(ig, cfg, table=at.BUILTIN_TABLE)
    assert a.chunk == b.chunk
    assert a.execution == b.execution
    assert dict(ra.chosen) == dict(rb.chosen)
