"""Property-based tests (hypothesis) for the adaptation layer:
``map.adapt_edges`` (importance-map update) and ``strat.adapt_nh``
(stratification re-allocation) — the invariants every iteration of the
driver relies on (DESIGN.md C2/C4)."""

import jax.numpy as jnp
import numpy as np
import pytest

# Property tests need hypothesis (requirements-dev.txt); skip the module —
# not the whole collection — where it is absent.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import map as vmap_  # noqa: E402
from repro.core import strat  # noqa: E402


def _random_monotone_edges(data, d, ninc):
    """Strictly monotone per-dimension edges over [0, 1] from random widths."""
    w = np.array([[data.draw(st.floats(0.05, 1.0)) for _ in range(ninc)]
                  for _ in range(d)], np.float32)
    cum = np.cumsum(w, axis=1) / w.sum(axis=1, keepdims=True)
    return jnp.asarray(np.concatenate([np.zeros((d, 1), np.float32), cum], 1))


# --- map.adapt_edges ---------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_adapt_edges_stay_strictly_monotone_with_fixed_endpoints(data):
    """For any positive accumulated weights the adapted grid is still a
    valid map: endpoints exactly fixed, interior strictly increasing."""
    d = data.draw(st.integers(1, 4))
    ninc = data.draw(st.sampled_from([4, 8, 16, 32]))
    alpha = data.draw(st.floats(0.1, 2.0))
    edges = _random_monotone_edges(data, d, ninc)
    sums = jnp.asarray(np.array(
        [[data.draw(st.floats(1e-2, 1e2)) for _ in range(ninc)]
         for _ in range(d)], np.float32))
    counts = jnp.full((d, ninc), 7.0, jnp.float32)
    new = vmap_.adapt_edges(edges, sums, counts, alpha)
    np.testing.assert_array_equal(np.asarray(new[:, 0]),
                                  np.asarray(edges[:, 0]))
    np.testing.assert_array_equal(np.asarray(new[:, -1]),
                                  np.asarray(edges[:, -1]))
    assert (np.diff(np.asarray(new), axis=1) > 0).all(), np.asarray(new)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_adapt_edges_uniform_weights_are_a_fixed_point(data):
    """Equal weight in every interval: each interval already holds an equal
    share, so the adaptation must leave ANY monotone grid unchanged."""
    d = data.draw(st.integers(1, 3))
    ninc = data.draw(st.sampled_from([4, 16, 64]))
    alpha = data.draw(st.floats(0.1, 2.0))
    c = data.draw(st.floats(1e-3, 1e3))
    edges = _random_monotone_edges(data, d, ninc)
    sums = jnp.full((d, ninc), c, jnp.float32)
    counts = jnp.full((d, ninc), 11.0, jnp.float32)
    new = vmap_.adapt_edges(edges, sums, counts, alpha)
    np.testing.assert_allclose(np.asarray(new), np.asarray(edges),
                               rtol=1e-5, atol=2e-6)


def test_adapt_edges_zero_weights_keep_grid_valid():
    """All-zero accumulators (e.g. an integrand that vanished everywhere)
    must not degenerate the grid."""
    edges = vmap_.uniform_edges([0.0, 0.0], [1.0, 1.0], 16)
    z = jnp.zeros((2, 16), jnp.float32)
    new = vmap_.adapt_edges(edges, z, z, 0.5)
    assert (np.diff(np.asarray(new), axis=1) > 0).all()
    np.testing.assert_allclose(np.asarray(new), np.asarray(edges), atol=1e-6)


# --- strat.adapt_nh ----------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_adapt_nh_total_near_neval_within_capacity(data):
    """The re-allocated totals stay inside the static capacity bound the
    fill's eval axis is sized for (DESIGN.md C2): each cube floors at 2 and
    the flooring loses < 1 eval per cube, so
    ``neval - n_cubes <= sum(n_h) <= eval_capacity(neval, n_cubes)``."""
    n_cubes = data.draw(st.integers(1, 512))
    neval = data.draw(st.integers(n_cubes * 2, 1_000_000))
    beta = data.draw(st.floats(0.1, 1.5))
    d_h = jnp.asarray(np.array(
        [data.draw(st.floats(0.0, 1e3)) for _ in range(n_cubes)], np.float32))
    n_h = strat.adapt_nh(d_h, beta, neval)
    assert (np.asarray(n_h) >= 2).all()          # per-cube floor
    tot = int(np.asarray(n_h, np.int64).sum())
    assert tot <= strat.eval_capacity(neval, n_cubes)
    assert tot >= neval - n_cubes


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_adapt_nh_beta_zero_is_uniform(data):
    """beta = 0 flattens the allocation signal: every cube gets the uniform
    share (classic-VEGAS identity, within one f32-rounding eval of
    ``uniform_nh``) regardless of d_h."""
    n_cubes = data.draw(st.integers(1, 256))
    neval = data.draw(st.integers(n_cubes * 2, 500_000))
    d_h = jnp.asarray(np.array(
        [data.draw(st.floats(0.0, 1e3)) for _ in range(n_cubes)], np.float32))
    n_h = np.asarray(strat.adapt_nh(d_h, 0.0, neval))
    assert (n_h == n_h[0]).all()                  # uniform across cubes
    uniform = np.asarray(strat.uniform_nh(neval, n_cubes))
    assert np.abs(n_h.astype(np.int64) - uniform.astype(np.int64)).max() <= 1


def test_adapt_nh_zero_signal_falls_back_to_uniform():
    """d_h == 0 everywhere (constant integrand): the p = d_h^beta / sum
    branch would be 0/0; the implementation must fall back to the uniform
    distribution instead."""
    n_h = np.asarray(strat.adapt_nh(jnp.zeros((8,)), 0.75, 8_000))
    assert (n_h == 1000).all()


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_adapt_nh_allocates_monotonically_in_the_signal(data):
    """More variance signal never gets fewer evals: the allocation is
    monotone in d_h (up to the shared floor)."""
    n_cubes = data.draw(st.integers(2, 128))
    neval = data.draw(st.integers(n_cubes * 4, 200_000))
    beta = data.draw(st.floats(0.25, 1.0))
    d = np.sort(np.array([data.draw(st.floats(0.0, 100.0))
                          for _ in range(n_cubes)], np.float32))
    n_h = np.asarray(strat.adapt_nh(jnp.asarray(d), beta, neval))
    assert (np.diff(n_h) >= 0).all()
