"""Unit + property tests for adaptive stratification."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Property tests need hypothesis (requirements-dev.txt); skip the module —
# not the whole collection — where it is absent.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import strat  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(neval=st.integers(100, 10_000_000), dim=st.integers(1, 16))
def test_choose_nstrat_respects_cap(neval, dim):
    ns = strat.choose_nstrat(neval, dim, max_cubes=1 << 16)
    assert ns >= 1
    assert ns**dim <= 1 << 16 or ns == 1


def test_map_evals_to_cubes_matches_repeat():
    n_h = jnp.array([3, 0, 2, 5, 1], jnp.int32)
    n_cap = 16
    cube, used = strat.map_evals_to_cubes(n_h, n_cap)
    expected = np.repeat(np.arange(5), np.asarray(n_h))
    np.testing.assert_array_equal(np.asarray(cube[: len(expected)]), expected)
    assert int(used) == 11
    assert (np.asarray(cube[len(expected):]) == 5).all()  # overflow bucket


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**30), n_cubes=st.integers(1, 300))
def test_map_evals_to_cubes_property(seed, n_cubes):
    key = jax.random.PRNGKey(seed)
    n_h = jax.random.randint(key, (n_cubes,), 0, 7, dtype=jnp.int32)
    total = int(n_h.sum())
    n_cap = total + 13
    cube, used = strat.map_evals_to_cubes(n_h, n_cap)
    assert int(used) == total
    counts = np.bincount(np.asarray(cube), minlength=n_cubes + 1)
    np.testing.assert_array_equal(counts[:n_cubes], np.asarray(n_h))
    assert counts[n_cubes] == n_cap - total


def test_cube_coords_roundtrip():
    nstrat, dim = 4, 5
    ids = jnp.arange(nstrat**dim, dtype=jnp.int32)
    coords = strat.cube_coords(ids, nstrat, dim)
    pows = nstrat ** np.arange(dim)
    rec = (np.asarray(coords) * pows).sum(-1)
    np.testing.assert_array_equal(rec, np.asarray(ids))
    assert (np.asarray(coords) >= 0).all() and (np.asarray(coords) < nstrat).all()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**30), beta=st.floats(0.0, 1.5))
def test_adapt_nh_invariants(seed, beta):
    key = jax.random.PRNGKey(seed)
    d_h = jax.random.uniform(key, (64,)) ** 3
    n_h = strat.adapt_nh(d_h, beta, neval=10_000)
    assert (np.asarray(n_h) >= 2).all()
    assert int(n_h.sum()) <= 10_000 + 2 * 64  # eval_capacity bound
    if beta == 0.0:  # uniform allocation
        assert len(np.unique(np.asarray(n_h))) == 1


def test_adapt_nh_allocates_to_high_variance():
    d_h = jnp.array([0.0, 0.1, 10.0, 0.1], jnp.float32)
    n_h = np.asarray(strat.adapt_nh(d_h, 0.75, neval=1000))
    assert n_h[2] > 10 * n_h[1]


def test_stratified_y_stays_in_cube():
    key = jax.random.PRNGKey(3)
    nstrat, dim, n = 3, 4, 256
    cube = jax.random.randint(key, (n,), 0, nstrat**dim, dtype=jnp.int32)
    u = jax.random.uniform(jax.random.fold_in(key, 1), (n, dim))
    y = strat.stratified_y(cube, u, nstrat)
    coords = strat.cube_coords(cube, nstrat, dim)
    assert (np.asarray(y) >= np.asarray(coords) / nstrat - 1e-7).all()
    assert (np.asarray(y) <= (np.asarray(coords) + 1) / nstrat + 1e-7).all()
