"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one decode step on CPU, asserting shapes and finiteness.  Full configs are
exercised (shape-only) via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, cfg.vocab)
    memory = (jax.random.normal(jax.random.fold_in(key, 2),
                                (b, cfg.xattn_memory_len, cfg.d_model))
              if cfg.xattn_memory_len else None)
    logits = T.forward(params, tokens, cfg, memory=memory)
    assert logits.shape == (b, s, cfg.vocab)
    assert jnp.isfinite(logits).all(), arch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_decode_step(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    b, cache_len = 2, 32
    cache = T.init_cache(cfg, b, cache_len, dtype=jnp.float32)
    if cfg.xattn_memory_len:
        # xattn memory kv must be populated (prefill normally does this)
        for j, blk in enumerate(cfg.blocks):
            if blk.mixer == "xattn":
                c = cache[f"slot{j}"]
                cache[f"slot{j}"] = jax.tree.map(
                    lambda t: jax.random.normal(key, t.shape, t.dtype) * 0.02, c)
    token = jax.random.randint(key, (b,), 0, cfg.vocab)
    logits, new_cache = T.decode_step(params, cache, token,
                                      jnp.array(0, jnp.int32), cfg)
    assert logits.shape == (b, cfg.vocab)
    assert jnp.isfinite(logits).all(), arch
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["yi_6b", "mamba2_1_3b", "h2o_danube3_4b"])
def test_prefill_then_decode_consistent(arch):
    """decode after prefill continues the sequence the forward pass predicts:
    prefill(t[:n]) + decode(t[n]) logits == forward(t[:n+1]) last logits."""
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    b, n = 2, 12
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (b, n + 1), 0, cfg.vocab)
    last_prefill, cache = T.prefill(params, tokens[:, :n], cfg, cache_len=n + 8,
                                    remat=False, cache_dtype=jnp.float32)
    full_logits = T.forward(params, tokens, cfg, remat=False)
    # decode one step with the prefilled cache
    logits, _ = T.decode_step(params, cache, tokens[:, n],
                              jnp.array(n, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits[:, -1]),
                               rtol=5e-2, atol=5e-3)
    np.testing.assert_allclose(np.asarray(last_prefill),
                               np.asarray(full_logits[:, n - 1]),
                               rtol=5e-2, atol=5e-3)


def test_param_counts_match_assignment():
    """Full configs land near their advertised sizes (6ND sanity anchor)."""
    expect = {
        "llama_3_2_vision_11b": (9.0e9, 12.5e9),
        "yi_6b": (5.5e9, 6.6e9),
        "mistral_large_123b": (118e9, 128e9),
        "h2o_danube3_4b": (3.2e9, 4.5e9),
        "smollm_135m": (0.12e9, 0.15e9),
        "mamba2_1_3b": (1.1e9, 1.5e9),
        "jamba_1_5_large_398b": (350e9, 440e9),
        "musicgen_large": (2.8e9, 3.6e9),
        "phi3_5_moe_42b": (40e9, 45e9),
        "kimi_k2_1t": (0.95e12, 1.1e12),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:.3e}")


def test_active_params_moe():
    kimi = configs.get("kimi_k2_1t")
    active = kimi.active_param_count()
    assert 25e9 <= active <= 40e9, f"{active:.3e}"  # "a32b"
    phi = configs.get("phi3_5_moe_42b")
    active = phi.active_param_count()
    assert 5e9 <= active <= 9e9, f"{active:.3e}"    # "a6.6b"
