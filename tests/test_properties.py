"""Property-based tests (hypothesis) for the aggregation layer:
``combine_results`` (inverse-variance weighting) and ``estimate_from_cubes``
(per-iteration estimate + stratification signal)."""

import jax.numpy as jnp
import numpy as np
import pytest

# Property tests need hypothesis (requirements-dev.txt); skip the module —
# not the whole collection — where it is absent.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.fill import FillResult, estimate_from_cubes  # noqa: E402
from repro.core.integrator import combine_results  # noqa: E402


def _results(means, sig2):
    return jnp.stack([jnp.asarray(means, jnp.float32),
                      jnp.asarray(sig2, jnp.float32)], axis=1)


means_st = st.lists(st.floats(-100.0, 100.0), min_size=1, max_size=12)
sig2_st = st.floats(1e-6, 1e3)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_combine_results_permutation_invariant(data):
    """With skip=0 and every iteration used, the combination is a weighted
    mean — permuting the iterations must not change it."""
    means = data.draw(means_st)
    n = len(means)
    sig2 = [data.draw(sig2_st) for _ in range(n)]
    perm = data.draw(st.permutations(range(n)))
    m0, s0, _, n0 = combine_results(_results(means, sig2), 0, n)
    mp, sp, _, np_ = combine_results(
        _results([means[i] for i in perm], [sig2[i] for i in perm]), 0, n)
    assert int(n0) == int(np_) == n
    assert float(mp) == pytest.approx(float(m0), rel=1e-4, abs=1e-5)
    assert float(sp) == pytest.approx(float(s0), rel=1e-4)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_combine_results_skip_masks_out_warmup(data):
    """Iterations before ``skip`` (and at/after ``n_done``) must not affect
    the result — replace them with arbitrary garbage and nothing changes."""
    means = data.draw(st.lists(st.floats(-100.0, 100.0), min_size=3,
                               max_size=10))
    n = len(means)
    sig2 = [data.draw(sig2_st) for _ in range(n)]
    skip = data.draw(st.integers(0, n - 1))
    n_done = data.draw(st.integers(skip + 1, n))
    garbage_mean = data.draw(st.floats(-1e6, 1e6))
    garbage_sig2 = data.draw(st.floats(1e-9, 1e9))
    means2, sig22 = list(means), list(sig2)
    for i in list(range(skip)) + list(range(n_done, n)):
        means2[i], sig22[i] = garbage_mean, garbage_sig2
    a = combine_results(_results(means, sig2), skip, n_done)
    b = combine_results(_results(means2, sig22), skip, n_done)
    assert float(a[0]) == pytest.approx(float(b[0]), rel=1e-6)
    assert float(a[1]) == pytest.approx(float(b[1]), rel=1e-6)
    assert int(a[3]) == int(b[3]) == n_done - skip


@settings(max_examples=40, deadline=None)
@given(mean=st.floats(-100.0, 100.0), sig2=sig2_st,
       pad=st.integers(0, 6))
def test_combine_results_single_iteration_identity(mean, sig2, pad):
    """One usable iteration: the combination IS that iteration (and chi2,
    with zero degrees of freedom, is 0)."""
    res = _results([mean] + [0.0] * pad, [sig2] + [np.inf] * pad)
    m, s, chi2, n = combine_results(res, 0, 1 + pad)
    assert int(n) == 1
    assert float(m) == pytest.approx(mean, rel=1e-5, abs=1e-6)
    assert float(s) == pytest.approx(float(np.sqrt(sig2)), rel=1e-5)
    # chi2 = (mean - m)^2 / sig2 amplifies the ~1-ulp f32 error of the
    # combined mean by 1/sig2; scale the "zero" tolerance accordingly.
    tol = 100.0 * (1.2e-7 * max(abs(mean), 1.0)) ** 2 / sig2
    assert float(chi2) == pytest.approx(0.0, abs=max(tol, 1e-6))


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_estimate_from_cubes_variance_nonnegative(data):
    """sigma2 and every d_h are >= 0 and finite for any accumulator state
    with s2 >= s1^2/n (Cauchy-Schwarz, true of any real sample)."""
    n_cubes = data.draw(st.integers(1, 32))
    nh = np.array(data.draw(st.lists(st.integers(1, 50), min_size=n_cubes,
                                     max_size=n_cubes)), np.float32)
    s1 = np.array(data.draw(st.lists(st.floats(-10.0, 10.0),
                                     min_size=n_cubes, max_size=n_cubes)),
                  np.float32)
    # s2 >= s1^2 / n_h + slack: realizable second moments
    slack = np.array(data.draw(st.lists(st.floats(0.0, 10.0),
                                        min_size=n_cubes, max_size=n_cubes)),
                     np.float32)
    s2 = s1 * s1 / nh + slack
    res = FillResult(jnp.zeros((1, 4)), jnp.zeros((1, 4)),
                     jnp.asarray(s1), jnp.asarray(s2))
    i_it, sigma2, d_h = estimate_from_cubes(res, jnp.asarray(nh, jnp.int32))
    assert np.isfinite(float(i_it))
    assert float(sigma2) >= 0.0
    assert (np.asarray(d_h) >= 0.0).all()
    assert np.isfinite(np.asarray(d_h)).all()


@settings(max_examples=30, deadline=None)
@given(c=st.floats(-5.0, 5.0), n_cubes=st.integers(1, 64),
       per_cube=st.integers(2, 20))
def test_estimate_from_cubes_constant_integrand_zero_variance(c, n_cubes,
                                                              per_cube):
    """A constant weight w=c in every cube: the estimate is exact (= c), the
    variance is exactly 0, and the stratification signal d_h is all-zero."""
    nh = jnp.full((n_cubes,), per_cube, jnp.int32)
    s1 = jnp.full((n_cubes,), c * per_cube, jnp.float32)
    s2 = jnp.full((n_cubes,), c * c * per_cube, jnp.float32)
    res = FillResult(jnp.zeros((1, 4)), jnp.zeros((1, 4)), s1, s2)
    i_it, sigma2, d_h = estimate_from_cubes(res, nh)
    # zero up to f32 rounding of the moments, whose natural scale is c^2
    assert float(i_it) == pytest.approx(c, rel=1e-4, abs=1e-6)
    assert float(sigma2) == pytest.approx(0.0, abs=1e-5 * max(c * c, 1.0))
    np.testing.assert_allclose(np.asarray(d_h), 0.0,
                               atol=2e-3 * max(abs(c), 1.0))
