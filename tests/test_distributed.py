"""Multi-device distribution tests (8 forced host devices, subprocess) and
checkpoint unit tests (in-process)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np


def test_multidevice_suite():
    """Runs the full 8-device suite (fill invariance, 2D mesh, run
    equivalence, elastic restart, straggler re-dispatch) in a subprocess so
    the forced device count never leaks into this process."""
    worker = os.path.join(os.path.dirname(__file__), "_dist_worker.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, worker], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL_OK" in out.stdout, out.stdout


def test_checkpoint_roundtrip(tmp_path):
    from repro.dist import checkpoint as CK
    tree = {"a": jnp.arange(5, dtype=jnp.float32),
            "b": (jnp.ones((2, 3)), jnp.array(7, jnp.int32))}
    p = str(tmp_path / "c.npz")
    CK.save(p, tree, step=3, meta={"note": "x"})
    back, step, meta = CK.restore(p, tree)
    assert step == 3 and meta["note"] == "x"
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_checkpoint_manager_retention(tmp_path):
    from repro.dist import checkpoint as CK
    mgr = CK.CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(s, {"x": jnp.array([s])})
    files = sorted(os.listdir(tmp_path))
    assert files == ["ckpt_3.npz", "ckpt_4.npz"]
    got, step, _ = mgr.restore_latest({"x": jnp.array([0])})
    assert step == 4 and int(got["x"][0]) == 4


def test_checkpoint_atomicity_no_partial(tmp_path):
    """A checkpoint file either exists complete or not at all: the tmp file
    from a failed write must not be confused with a checkpoint."""
    from repro.dist import checkpoint as CK
    assert CK.latest(str(tmp_path)) is None
    (tmp_path / "ckpt_9.npz.tmp").write_bytes(b"garbage")
    assert CK.latest(str(tmp_path)) is None
