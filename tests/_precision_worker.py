"""Subprocess worker for the Kahan shard-boundary regression (§15).

Device-count invariance at Kahan-level accuracy: the sharded fill carries
the compensation through the psum (`make_local_fill` returns
psum(sums) - psum(comp)), so the combined moments on ANY shard count stay
within a few ulps of the f64 ground truth — the per-shard partials are each
exact to ~1 ulp and the boundary loses nothing beyond the final psum's own
rounding.  This worker forces 4 host devices and asserts 1-, 2- and 4-shard
fills all sit at that floor, and within a few ulps of EACH OTHER.  The
bounds are ~6x the measured error; a combination that dropped whole
partials, double-counted a shard, or fell back to plain per-shard f32
summation blows them by orders of magnitude.  Run by tests/test_precision.py
in a subprocess so the forced device count never leaks."""

import os

from repro.launch import env as launch_env

launch_env.set_host_device_count(4)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ["JAX_ENABLE_X64"] = "1"   # for the f64 ground-truth fill

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import fill as F  # noqa: E402
from repro.core import integrator as I  # noqa: E402
from repro.core.integrands import make_cosine  # noqa: E402
from repro.dist import sharded_fill as SF  # noqa: E402

FIELDS = ("map_sums", "map_counts", "cube_s1", "cube_s2")


def main():
    assert jax.device_count() == 4, jax.device_count()
    ig = make_cosine(dim=2)
    # Accumulation-hostile: many chunks per shard, so per-shard summation
    # error (were it not Kahan-carried) would dominate the bound.
    cfg = I.VegasConfig(neval=32_768, max_it=1, skip=0, ninc=64, chunk=512)
    rc = cfg.resolve(ig.dim)
    st = I.init_state(ig, rc, jax.random.PRNGKey(0))
    key_it = jax.random.fold_in(st.key, st.it)

    truth = F.fill_reference(st.edges, st.n_h, key_it, ig, nstrat=rc.nstrat,
                             n_cap=rc.n_cap, chunk=rc.chunk,
                             accum_dtype=jnp.float64)
    truth = {f: np.asarray(getattr(truth, f), np.float64) for f in FIELDS}
    scale = {f: max(1.0, float(np.max(np.abs(t))))
             for f, t in truth.items()}

    results = {}
    for k in (1, 2, 4):
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:k]), ("data",))
        fill = SF.make_sharded_fill(mesh, ("data",), rc)
        res = fill(st.edges, st.n_h, key_it, ig)
        # Pull to host before comparing: arrays from different meshes must
        # not meet inside a jitted op.
        results[k] = {f: np.asarray(getattr(res, f), np.float64)
                      for f in FIELDS}
        for f in FIELDS:
            err = np.max(np.abs(results[k][f] - truth[f])) / scale[f]
            assert err < 5e-6, (k, f, err)
        print(f"CHECK shards={k} at the Kahan floor OK")

    for k in (2, 4):
        for f in FIELDS:
            spread = (np.max(np.abs(results[k][f] - results[1][f]))
                      / scale[f])
            assert spread < 5e-6, (k, f, spread)
    print("CHECK device-count invariance OK")
    print("ALL_OK")


if __name__ == "__main__":
    main()
