"""P-V3 fused streaming fill: RNG contract, fused-kernel oracle parity,
memory-footprint (jaxpr) checks, and interpret-mode autodetection.

The headline invariants of the fused path (kernels/vegas_fill.py,
DESIGN.md §7):
  * in-kernel uniforms == ``jax.random.uniform(fold_in(key, g), (chunk, d))``
    BIT-FOR-BIT, under both threefry counter layouts;
  * no per-eval float array exists anywhere in the traced program — HBM
    traffic is the sorted int32 cube-id input plus O(accumulators);
  * FillResults match ``fill_reference`` at the standard parity tolerances
    (exercised by tests/test_fill_parity.py's three-way sweep).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels as K
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels import vegas_fill as vk


def _ig(x):
    return jnp.sum(x * x, axis=-1) + 1.0


# ---------------------------------------------------------------------------
# RNG contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk,d,tile", [
    (256, 4, 64),     # pow2 everything
    (100, 3, 50),     # nothing a power of two
    (96, 2, 96),      # single tile == chunk
    (25, 3, 25),      # chunk*d odd: the padded-counter path
    (512, 1, 128),    # d=1
])
@pytest.mark.parametrize("partitionable", [True, False])
def test_inkernel_uniforms_bitexact(chunk, d, tile, partitionable):
    """In-kernel tile uniforms reassemble to uniform(fold_in(key, g)) exactly
    (not allclose: np.array_equal on the raw f32 bits)."""
    old = bool(jax.config.jax_threefry_partitionable)
    jax.config.update("jax_threefry_partitionable", partitionable)
    try:
        key = jax.random.PRNGKey(7)
        for g in (0, 5):
            k = jax.random.fold_in(key, g)
            expected = jax.random.uniform(k, (chunk, d), dtype=jnp.float32)
            got = vk.chunk_uniforms(kops.key_bits(k), chunk=chunk, d=d,
                                    tile=tile)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(expected))
    finally:
        jax.config.update("jax_threefry_partitionable", old)


def test_inkernel_uniforms_tile_invariant():
    """The tile decomposition does not change the stream: any tile size that
    divides the chunk reproduces the same (chunk, d) block."""
    key = jax.random.fold_in(jax.random.PRNGKey(3), 2)
    kb = kops.key_bits(key)
    whole = vk.chunk_uniforms(kb, chunk=240, d=3)
    for tile in (240, 120, 80, 48, 16):
        np.testing.assert_array_equal(
            np.asarray(vk.chunk_uniforms(kb, chunk=240, d=3, tile=tile)),
            np.asarray(whole))


def test_typed_key_bits_roundtrip():
    """key_bits handles both legacy raw and new-style typed keys."""
    raw = jax.random.PRNGKey(11)
    typed = jax.random.key(11)
    np.testing.assert_array_equal(np.asarray(kops.key_bits(raw)),
                                  np.asarray(kops.key_bits(typed)))


# ---------------------------------------------------------------------------
# Fused kernel vs oracle (sorted ids, masked tail, odd n_cubes)
# ---------------------------------------------------------------------------

def _sorted_inputs(key, chunk, d, ninc, nstrat, n_live):
    """Sorted cube ids with a masked overflow tail, as ops.fill produces."""
    n_cubes = nstrat**d
    ids = jnp.sort(jax.random.randint(key, (n_live,), 0, n_cubes,
                                      dtype=jnp.int32))
    cube = jnp.concatenate(
        [ids, jnp.full((chunk - n_live,), n_cubes, jnp.int32)])
    # dtype pinned: the fused path is f32-only (RNG contract), and under
    # JAX_ENABLE_X64=1 the float defaults here would silently become f64.
    w = jax.random.uniform(jax.random.fold_in(key, 1), (d, ninc),
                           minval=0.05, maxval=1.0, dtype=jnp.float32)
    w = w / w.sum(1, keepdims=True)
    edges_lo = jnp.concatenate(
        [jnp.zeros((d, 1), jnp.float32), jnp.cumsum(w, 1)[:, :-1]], axis=1)
    return cube.reshape(chunk, 1), edges_lo, w, n_cubes


@pytest.mark.parametrize("chunk,d,ninc,nstrat,tile,n_live", [
    (256, 3, 32, 3, 128, 200),    # n_cubes=27: far from a tile multiple
    (256, 2, 64, 5, 64, 256),     # no masked tail
    (384, 4, 50, 2, 96, 120),     # mostly masked; ninc not a power of two
    (128, 1, 16, 7, 128, 100),    # d=1
])
def test_fused_kernel_matches_oracle(chunk, d, ninc, nstrat, tile, n_live):
    """vegas_fill_fused == fused oracle when fed identical uniforms.

    Note: random sorted ids may repeat a cube more than ``tile`` times but
    never skip backwards, so each tile still touches a contiguous id window —
    the same invariant ops.fill's searchsorted ids satisfy.
    """
    key = jax.random.PRNGKey(chunk + d)
    cube, edges_lo, widths, n_cubes = _sorted_inputs(
        key, chunk, d, ninc, nstrat, n_live)
    k = jax.random.fold_in(key, 9)
    u = vk.chunk_uniforms(kops.key_bits(k), chunk=chunk, d=d)
    ms_r, mc_r, s1_r, s2_r = kref.vegas_fill_fused_ref(
        u, cube, edges_lo, widths, nstrat=nstrat, n_cubes=n_cubes,
        integrand=_ig)
    ms, mc, s1p, s2p = vk.vegas_fill_fused(
        kops.key_bits(k).reshape(1, 2), cube, edges_lo, widths,
        nstrat=nstrat, n_cubes=n_cubes, integrand=_ig, tile=tile,
        interpret=True)
    s1 = s1p.reshape(-1)[:n_cubes]
    s2 = s2p.reshape(-1)[:n_cubes]
    for got, want, tag in [(ms, ms_r, "ms"), (mc, mc_r, "mc"),
                           (s1, s1_r, "s1"), (s2, s2_r, "s2")]:
        scale = float(np.abs(np.asarray(want)).max()) or 1.0
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5 * scale, err_msg=tag)
    # the pad region beyond n_cubes holds only clipped zero contributions
    assert float(jnp.abs(s1p.reshape(-1)[n_cubes:]).max(initial=0.0)) == 0.0


def test_fused_kernel_all_masked():
    """Every eval in the overflow bucket -> all accumulators exactly zero."""
    chunk, d, ninc, nstrat = 128, 2, 16, 3
    cube, edges_lo, widths, n_cubes = _sorted_inputs(
        jax.random.PRNGKey(0), chunk, d, ninc, nstrat, n_live=0)
    ms, mc, s1p, s2p = vk.vegas_fill_fused(
        kops.key_bits(jax.random.PRNGKey(1)).reshape(1, 2), cube, edges_lo,
        widths, nstrat=nstrat, n_cubes=n_cubes, integrand=_ig, tile=64,
        interpret=True)
    for a in (ms, mc, s1p, s2p):
        assert float(jnp.abs(a).max()) == 0.0


# ---------------------------------------------------------------------------
# Memory footprint: the fused jaxpr has no per-eval float array
# ---------------------------------------------------------------------------

def _float_dims(jaxpr, dims):
    """Collect every dimension of every float aval in jaxpr, recursively
    (scan bodies, pallas kernel jaxprs, closed calls)."""
    from jax.core import Jaxpr, ClosedJaxpr

    def visit(p):
        if isinstance(p, ClosedJaxpr):
            visit(p.jaxpr)
            return
        if not isinstance(p, Jaxpr):
            if isinstance(p, (list, tuple)):
                for x in p:
                    visit(x)
            elif isinstance(p, dict):
                for x in p.values():
                    visit(x)
            return
        for eqn in p.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                if (aval is not None and hasattr(aval, "shape")
                        and hasattr(aval, "dtype")
                        and jnp.issubdtype(aval.dtype, jnp.floating)):
                    dims.update(aval.shape)
            for param in eqn.params.values():
                visit(param)

    visit(jaxpr)
    return dims


def _fill_jaxpr(fused: bool, *, chunk=2048, n_chunks=4, d=2, ninc=32,
                nstrat=3, rng_in_kernel=None):
    from repro.core import map as vmap_
    from repro.core import strat
    n_cubes = nstrat**d
    n_cap = chunk * n_chunks
    edges = vmap_.uniform_edges([0.0] * d, [1.0] * d, ninc)
    n_h = strat.uniform_nh(n_cap - n_cubes, n_cubes)
    closed = jax.make_jaxpr(
        lambda e, nh, k: kops.fill(e, nh, k, _ig, nstrat=nstrat, n_cap=n_cap,
                                   chunk=chunk, interpret=True,
                                   fused_cubes=fused, tile=256,
                                   rng_in_kernel=rng_in_kernel))(
        edges, n_h, jax.random.PRNGKey(0))
    return closed, chunk, n_cap


def test_fused_jaxpr_has_no_per_eval_float_array():
    """Acceptance check on the streaming program (in-kernel RNG, what runs
    compiled on TPU): NO float array with a dimension at chunk scale or above
    exists — neither the (chunk, d) uniforms nor the (chunk, 1) weight output
    survive the fusion (the only chunk-sized array left is the int32 cube-id
    input).  The baseline program, by contrast, still materializes both."""
    fused, chunk, n_cap = _fill_jaxpr(fused=True, rng_in_kernel=True)
    dims = _float_dims(fused.jaxpr, set())
    assert max(dims) < chunk, f"per-eval float array leaked: dims={dims}"

    baseline, chunk, n_cap = _fill_jaxpr(fused=False)
    dims_b = _float_dims(baseline.jaxpr, set())
    assert max(dims_b) >= chunk, "baseline should materialize per-chunk floats"


def test_fused_hybrid_jaxpr_has_no_weight_output():
    """The interpret-mode hybrid (uniforms precomputed per chunk, everything
    else fused) still has no per-eval WEIGHT array: its only chunk-sized
    float is the uniforms input block."""
    hybrid, chunk, n_cap = _fill_jaxpr(fused=True, rng_in_kernel=False)
    dims = _float_dims(hybrid.jaxpr, set())
    assert max(dims) <= chunk, f"beyond-chunk float array leaked: dims={dims}"
    # chunk-sized floats exist (u) but only with the d-column shape — the
    # (chunk, 1) weight output shape must be gone.
    shapes = set()

    from jax.core import Jaxpr, ClosedJaxpr

    def visit(p):
        if isinstance(p, ClosedJaxpr):
            return visit(p.jaxpr)
        if isinstance(p, (list, tuple)):
            return [visit(x) for x in p]
        if isinstance(p, dict):
            return [visit(x) for x in p.values()]
        if not isinstance(p, Jaxpr):
            return
        for eqn in p.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                if (aval is not None and getattr(aval, "shape", None)
                        and hasattr(aval, "dtype")
                        and jnp.issubdtype(aval.dtype, jnp.floating)):
                    shapes.add(tuple(aval.shape))
            for param in eqn.params.values():
                visit(param)

    visit(hybrid.jaxpr)
    assert (chunk, 1) not in shapes, "per-eval weight array leaked"


def test_fused_jaxpr_no_ncap_array_any_dtype():
    """Scan-chunking keeps EVERY array (any dtype) below n_cap: live memory
    is bounded by one chunk, not by the eval capacity."""
    from jax.core import Jaxpr, ClosedJaxpr

    closed, chunk, n_cap = _fill_jaxpr(fused=True)
    dims = set()

    def visit(p):
        if isinstance(p, ClosedJaxpr):
            return visit(p.jaxpr)
        if isinstance(p, (list, tuple)):
            return [visit(x) for x in p]
        if isinstance(p, dict):
            return [visit(x) for x in p.values()]
        if not isinstance(p, Jaxpr):
            return
        for eqn in p.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                if aval is not None and getattr(aval, "shape", None):
                    dims.update(aval.shape)
            for param in eqn.params.values():
                visit(param)

    visit(closed.jaxpr)
    assert max(dims) < n_cap, f"n_cap-sized array leaked: {sorted(dims)[-3:]}"


# ---------------------------------------------------------------------------
# interpret autodetect + tile autotune
# ---------------------------------------------------------------------------

def test_backend_default_and_resolve_on_cpu(caplog):
    assert jax.default_backend() == "cpu"
    assert K.backend_default() == "ref"  # neither TPU nor GPU -> ref
    K._announce.cache_clear()
    with caplog.at_level("INFO", logger="repro.kernels"):
        assert K.resolve_interpret(None) is True
        assert K.resolve_interpret(True) is True
        assert K.resolve_interpret(False) is False  # honored but warned
        assert K.resolve_interpret(None, family="gpu") is True
    text = caplog.text
    assert "INTERPRET on platform=cpu" in text
    assert "autodetected" in text
    assert "only supported on TPU" in text  # the loud explicit-False warning
    assert "[gpu kernel]" in text  # family tag in the announce line
    K._announce.cache_clear()


def test_config_interpret_none_runs_end_to_end():
    """VegasConfig's default interpret=None autodetects and completes a tiny
    fused pallas run on CPU."""
    from repro.core import VegasConfig, run
    from repro.core import integrands as igs
    ig = igs.make_cosine(dim=2)
    r = run(ig, VegasConfig(neval=4_000, max_it=3, ninc=16, chunk=2048,
                            backend="pallas"),
            key=jax.random.PRNGKey(0))
    assert np.isfinite(r.mean) and r.n_it == 3


@pytest.mark.parametrize("chunk,d,ninc", [
    (16_384, 4, 1024), (2048, 2, 32), (100, 3, 50), (16_384, 16, 1024),
])
def test_autotune_tile_divides_and_fits(chunk, d, ninc):
    t = kops.autotune_tile(chunk, d, ninc, n_cubes=4096)
    assert chunk % t == 0 and 1 <= t <= 1024
    span = vk.span_for_tile(t)
    assert 4 * (d * t * ninc + t * span + 8 * t * d + 3 * d * ninc) <= 8 << 20


def test_fused_rejects_non_f32():
    from repro.core import map as vmap_
    from repro.core import strat
    edges = vmap_.uniform_edges([0.0, 0.0], [1.0, 1.0], 16)
    n_h = strat.uniform_nh(512, 9)
    with pytest.raises(ValueError, match="f32-only"):
        kops.fill(edges, n_h, jax.random.PRNGKey(0), _ig, nstrat=3,
                  n_cap=512, chunk=512, dtype=jnp.float16, fused_cubes=True)
