"""Determinism + resume regressions for the two loop paths.

``run`` executes either as one jitted on-device ``fori_loop`` program
(default) or as a host-side loop (when ``checkpoint_cb`` is set); both must
be bit-deterministic, agree with each other, and compose with
checkpoint-resume under a larger ``max_it``."""

import jax
import numpy as np
import pytest

from repro.core import VegasConfig, run
from repro.core import integrands as igs

CFG = VegasConfig(neval=12_000, max_it=6, skip=2, ninc=32, chunk=4096)
KEY = jax.random.PRNGKey(21)


def _host_loop_run(ig, cfg, key, state=None):
    # any checkpoint_cb forces the per-iteration host loop
    return run(ig, cfg, key=key, state=state, checkpoint_cb=lambda it, s: None)


def test_fori_loop_path_bit_deterministic():
    ig = igs.make_cosine(dim=3)
    r1 = run(ig, CFG, key=KEY)
    r2 = run(ig, CFG, key=KEY)
    assert r1.mean == r2.mean  # bit-identical, not approx
    assert r1.sdev == r2.sdev
    np.testing.assert_array_equal(np.asarray(r1.state.results),
                                  np.asarray(r2.state.results))


def test_host_loop_path_bit_deterministic():
    ig = igs.make_cosine(dim=3)
    r1 = _host_loop_run(ig, CFG, KEY)
    r2 = _host_loop_run(ig, CFG, KEY)
    assert r1.mean == r2.mean
    assert r1.sdev == r2.sdev


def test_fori_loop_matches_host_loop_bitwise():
    """The on-device loop is the same program as the stepped host loop: the
    per-iteration results must agree bitwise (checked on CPU; both paths
    fold the same per-iteration keys and run the same iteration_step)."""
    ig = igs.make_gaussian(dim=2, sigma=0.1)
    r_fori = run(ig, CFG, key=KEY)
    r_host = _host_loop_run(ig, CFG, KEY)
    np.testing.assert_array_equal(np.asarray(r_fori.state.results),
                                  np.asarray(r_host.state.results))
    np.testing.assert_array_equal(np.asarray(r_fori.state.edges),
                                  np.asarray(r_host.state.edges))
    assert r_fori.mean == r_host.mean


def test_resume_with_larger_max_it_matches_uninterrupted():
    """Checkpoint after 3 of 8 iterations, resume under max_it=8: identical
    to the uninterrupted 8-iteration run — on BOTH loop paths."""
    ig = igs.make_cosine(dim=4)
    kw = dict(neval=12_000, skip=2, ninc=32, chunk=4096)
    full = run(ig, VegasConfig(max_it=8, **kw), key=KEY)

    saved = {}
    run(ig, VegasConfig(max_it=3, **kw), key=KEY,
        checkpoint_cb=lambda it, s: saved.__setitem__("state", s))

    resumed_fori = run(ig, VegasConfig(max_it=8, **kw), key=KEY,
                       state=saved["state"])
    assert resumed_fori.mean == pytest.approx(full.mean, rel=1e-6)
    assert resumed_fori.sdev == pytest.approx(full.sdev, rel=1e-6)

    resumed_host = _host_loop_run(ig, VegasConfig(max_it=8, **kw), KEY,
                                  state=saved["state"])
    assert resumed_host.mean == pytest.approx(full.mean, rel=1e-6)


def test_resume_state_not_mutated_by_donation():
    """run() donates its working state to the jitted program; the caller's
    state object must survive for a second resume."""
    ig = igs.make_cosine(dim=3)
    half = run(ig, VegasConfig(neval=12_000, max_it=3, skip=1, ninc=32,
                               chunk=4096), key=KEY)
    cfg8 = VegasConfig(neval=12_000, max_it=6, skip=1, ninc=32, chunk=4096)
    r1 = run(ig, cfg8, key=KEY, state=half.state)
    r2 = run(ig, cfg8, key=KEY, state=half.state)  # state still alive
    assert r1.mean == r2.mean


def test_batched_engine_bit_deterministic():
    from repro.batch import run_batch
    from repro.batch.family import make_gaussian_family
    fam = make_gaussian_family(np.array([0.3, 0.7]))
    r1 = run_batch(fam, CFG, key=KEY)
    r2 = run_batch(fam, CFG, key=KEY)
    np.testing.assert_array_equal(r1.mean, r2.mean)
    np.testing.assert_array_equal(r1.sdev, r2.sdev)
