"""Precision-policy conformance and regression suite (DESIGN.md §15).

The planted-drift design: a CONSTANT integrand c on a uniform power-of-2
map makes every jacobian exactly 1.0 in f32, so each valid sample
contributes exactly ``fl32(c)`` and the true per-cube first moment is the
integer sample count times ``float64(fl32(c))`` — computable exactly, with
zero Monte Carlo noise.  Any deviation IS accumulation rounding.  The
per-cube counts are chosen non-power-of-2 (``neval = 4 * 32749``): with
power-of-2 counts of equal values, pairwise tree reduction is EXACT (every
partial sum is a power-of-2 multiple, and scaling by 2 is exact in
floating point), which silently zeroes the very drift being measured.

Expected ordering differs by where the backend widens (§15):

* ref / pallas-gpu widen the weights BEFORE the within-chunk sums, so
  f32 > Kahan > widened, and the widened error is exactly 0 here.
* pallas-fused keeps products AND the per-tile one-hot matmul in f32 for
  the MXU and widens the per-tile partial sums after — so Kahan and
  widening both eliminate only the cross-chunk error and share the same
  within-chunk f32 floor: f32 > Kahan ~= widened > 0.
"""

import dataclasses
import os
import subprocess
import sys
from contextlib import contextmanager

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.engine as E
from repro.core import fill as fill_mod
from repro.core import integrator as I
from repro.core import map as vmap_
from repro.core import strat
from repro.core.integrands import make_cosine
from repro.engine import backends as backends_mod

D, NINC, NSTRAT = 2, 16, 2
N_CUBES = NSTRAT**D
C32 = np.float32(1 / 3)


@contextmanager
def _x64(flag: bool):
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", flag)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", old)


@pytest.fixture
def x64():
    with _x64(True):
        yield


class _Planted:
    lower = np.zeros(D)
    upper = np.ones(D)
    dim = D

    def __call__(self, x):
        return jnp.full(x.shape[:1], C32, jnp.float32)


def _planted_errors(fill_fn, neval, chunk, **extra):
    """max |cube_s1 - exact| for plain-f32 / Kahan / widened-f64 fills."""
    edges = vmap_.uniform_edges(np.zeros(D), np.ones(D), NINC, jnp.float32)
    n_h = strat.uniform_nh(neval, N_CUBES)
    n_cap = ((int(n_h.sum()) + chunk - 1) // chunk) * chunk
    kw = dict(nstrat=NSTRAT, n_cap=n_cap, chunk=chunk, dtype=jnp.float32,
              **extra)
    key = jax.random.PRNGKey(0)
    exact = np.asarray(n_h, np.float64) * np.float64(C32)
    out = {}
    for tag, kkw in [("f32", {}), ("kahan", dict(kahan=True)),
                     ("wide", dict(accum_dtype=jnp.float64))]:
        res = fill_fn(edges, n_h, key, _Planted(), **kw, **kkw)
        out[tag] = float(np.max(np.abs(
            np.asarray(res.cube_s1, np.float64) - exact)))
    return out


# --- conformance: planted-sum error ordering per backend ---------------------

def test_error_ordering_ref(x64):
    e = _planted_errors(fill_mod.fill_reference, neval=4 * 32749, chunk=128)
    assert e["f32"] > e["kahan"] > e["wide"], e
    # ref widens before the scatter: integer counts x one f32 value sum
    # exactly in f64.
    assert e["wide"] == 0.0, e


def test_error_ordering_gpu_interpret(x64):
    e = _planted_errors(fill_mod.fill_pallas_gpu, neval=4 * 32749, chunk=128,
                        interpret=True)
    assert e["f32"] > e["kahan"] > e["wide"], e
    assert e["wide"] == 0.0, e


def test_error_ordering_fused_interpret(x64):
    # Power-of-2 neval here: per-chunk partials repeat identically, making
    # the within-chunk floor shared by Kahan and widening bit-identical.
    e = _planted_errors(fill_mod.fill_pallas, neval=1 << 17, chunk=128,
                        interpret=True, fused_cubes=True)
    # Products and per-tile matmul stay f32 (§15): widening removes only the
    # cross-chunk drift, exactly like Kahan — both beat plain f32, neither
    # goes below the in-kernel f32 floor.
    assert e["f32"] > e["kahan"], e
    assert e["f32"] > e["wide"], e
    assert e["wide"] > 0.0, e
    assert abs(e["kahan"] - e["wide"]) <= 1e-12 * max(e["kahan"], 1.0), e


def test_pure_f64_reference_tiny(x64):
    """A pure-f64 run (sample AND accum float64, ref backend) sits far below
    every f32-sampled variant: the planted sum is exact to f64 rounding."""
    edges = vmap_.uniform_edges(np.zeros(D), np.ones(D), NINC, jnp.float64)
    n_h = strat.uniform_nh(4 * 32749, N_CUBES)
    n_cap = ((int(n_h.sum()) + 127) // 128) * 128
    res = fill_mod.fill_reference(edges, n_h, jax.random.PRNGKey(0),
                                  _Planted(), nstrat=NSTRAT, n_cap=n_cap,
                                  chunk=128, dtype=jnp.float64)
    exact = np.asarray(n_h, np.float64) * np.float64(C32)
    assert res.cube_s1.dtype == jnp.float64
    assert float(np.max(np.abs(np.asarray(res.cube_s1) - exact))) < 1e-8


def test_widened_fill_result_dtype(x64):
    """accum_dtype=f64 fills return f64 moments on every backend; the
    default policy still returns f32 (no silent promotion)."""
    for fn, extra in [(fill_mod.fill_reference, {}),
                      (fill_mod.fill_pallas,
                       dict(interpret=True, fused_cubes=True)),
                      (fill_mod.fill_pallas_gpu, dict(interpret=True))]:
        edges = vmap_.uniform_edges(np.zeros(D), np.ones(D), NINC,
                                    jnp.float32)
        n_h = strat.uniform_nh(1024, N_CUBES)
        kw = dict(nstrat=NSTRAT, n_cap=1024, chunk=512, dtype=jnp.float32,
                  **extra)
        wide = fn(edges, n_h, jax.random.PRNGKey(0), _Planted(), **kw,
                  accum_dtype=jnp.float64)
        plain = fn(edges, n_h, jax.random.PRNGKey(0), _Planted(), **kw)
        for leaf in jax.tree.leaves(wide):
            assert leaf.dtype == jnp.float64, fn
        for leaf in jax.tree.leaves(plain):
            assert leaf.dtype == jnp.float32, fn


def test_return_comp_requires_kahan():
    edges = vmap_.uniform_edges(np.zeros(D), np.ones(D), NINC, jnp.float32)
    n_h = strat.uniform_nh(1024, N_CUBES)
    kw = dict(nstrat=NSTRAT, n_cap=1024, chunk=512, dtype=jnp.float32)
    with pytest.raises(ValueError, match="kahan"):
        fill_mod.fill_reference(edges, n_h, jax.random.PRNGKey(0),
                                _Planted(), **kw, return_comp=True)
    out, comp = fill_mod.fill_reference(edges, n_h, jax.random.PRNGKey(0),
                                        _Planted(), **kw, kahan=True,
                                        return_comp=True)
    assert jax.tree.structure(out) == jax.tree.structure(comp)


# --- plan validation: the PlanError matrix -----------------------------------

def _cfg(backend, accum=None, sample=None, dtype="float32", **exec_kw):
    prec = (E.PrecisionPolicy(sample_dtype=sample, accum_dtype=accum)
            if (accum or sample) else None)
    return I.VegasConfig(
        neval=4096, max_it=2, skip=1, ninc=32, chunk=1024, dtype=dtype,
        execution=E.ExecutionConfig(backend=backend, precision=prec,
                                    **exec_kw))


def test_plan_rejects_f64_samples_on_kernel_backends():
    with _x64(True):
        for backend in ("pallas", "pallas-fused", "pallas-gpu"):
            with pytest.raises(E.PlanError, match="supports dtypes"):
                E.make_plan(make_cosine(dim=2), _cfg(backend,
                                                     dtype="float64"))


def test_plan_rejects_unsupported_precision_pair(monkeypatch):
    spec = backends_mod.get("ref")
    monkeypatch.setitem(
        backends_mod._REGISTRY, "ref",
        dataclasses.replace(spec, precisions=(("float32", "float32"),)))
    with _x64(True):
        with pytest.raises(E.PlanError, match="precision pairs"):
            E.make_plan(make_cosine(dim=2), _cfg("ref", accum="float64"))


def test_plan_rejects_sample_dtype_conflict():
    with pytest.raises(E.PlanError, match="conflicts with cfg.dtype"):
        E.make_plan(make_cosine(dim=2), _cfg("ref", sample="float64"))


def test_plan_rejects_widened_accum_without_x64():
    with _x64(False):
        with pytest.raises(E.PlanError, match="needs x64 enabled"):
            E.make_plan(make_cosine(dim=2), _cfg("ref", accum="float64"))


def test_plan_rejects_grad_with_widened_accum(x64):
    with pytest.raises(E.PlanError, match="grad \\+ widened"):
        E.make_plan(make_cosine(dim=2),
                    _cfg("ref", accum="float64",
                         grad=E.GradPolicy(mode="pathwise")))


def test_plan_accepts_widened_and_narrowed_policies(x64):
    # f32 samples -> f64 accumulators on every kernel backend.
    for backend in ("ref", "pallas", "pallas-fused", "pallas-gpu"):
        kw = {} if backend == "ref" else dict(interpret=True)
        plan = E.make_plan(make_cosine(dim=2),
                           _cfg(backend, accum="float64", **kw))
        assert plan.precision.widened
        assert "float32->float64" in plan.describe()
    # ...and ref also accepts the narrowing direction (f64 -> f32).
    plan = E.make_plan(make_cosine(dim=2),
                       _cfg("ref", accum="float32", dtype="float64"))
    assert not plan.precision.widened
    assert "float64->float32" in plan.describe()


def test_widened_plan_end_to_end(x64):
    """ISSUE 10 acceptance: pallas-fused and pallas-gpu accept and execute
    accum_dtype=float64 plans (interpret mode); estimates stay sane."""
    ig = make_cosine(dim=2)
    for backend in ("ref", "pallas-fused", "pallas-gpu"):
        kw = {} if backend == "ref" else dict(interpret=True)
        plan = E.make_plan(ig, _cfg(backend, accum="float64", **kw))
        res = E.execute(plan, key=jax.random.PRNGKey(3))
        assert np.isfinite(res.mean) and np.isfinite(res.sdev)
        assert abs(res.mean - ig.target) < max(5 * res.sdev, 5e-2), \
            (backend, res.mean, ig.target)


def test_loop_carry_stays_in_sample_dtype(x64):
    """Widened moments must not promote the loop-carried state: adapted
    edges (next iteration's samples) are cast back to the sample dtype."""
    ig = make_cosine(dim=2)
    rc = _cfg("ref", accum="float64").resolve(ig.dim)
    st = I.init_state(ig, rc, jax.random.PRNGKey(0))
    st2 = I.iteration_step(st, ig, rc)
    assert st2.edges.dtype == jnp.float32
    assert st2.results.dtype == jnp.float32


# --- autotuner budget: 8-byte accumulators shrink the candidate sets ---------

def test_valid_tiles_shrink_under_f64_accum():
    from repro.kernels.ops import valid_tiles
    kw = dict(chunk=4096, d=4, ninc=1024, n_cubes=1 << 18)
    t32 = valid_tiles(**kw, accum_itemsize=4)
    t64 = valid_tiles(**kw, accum_itemsize=8)
    assert set(t64) < set(t32), (t32, t64)
    assert max(t64) < max(t32), (t32, t64)


def test_valid_blocks_shrink_under_f64_accum():
    from repro.kernels.gpu_fill import valid_blocks
    kw = dict(chunk=4096, d=4, ninc=1024)
    b32 = valid_blocks(**kw, accum_itemsize=4)
    b64 = valid_blocks(**kw, accum_itemsize=8)
    assert set(b64) < set(b32), (b32, b64)
    assert max(b64) < max(b32), (b32, b64)


def test_autotune_prices_accum_itemsize():
    from repro.engine.autotune import _accum_itemsize
    assert _accum_itemsize(E.ExecutionConfig()) == 4
    assert _accum_itemsize(E.ExecutionConfig(
        precision=E.PrecisionPolicy(accum_dtype="float64"))) == 8


# --- satellite regressions ---------------------------------------------------

def test_serve_normalizes_params_to_request_dtype():
    """Regression: _norm_1d/_norm_2d coerced params to float64
    unconditionally; a float64 param array closed over by the family would
    promote every fill product behind the plan's back."""
    from repro.serve import IntegrationRequest, SweepService
    from repro.serve.service import _norm_1d

    assert _norm_1d([0.5]).dtype == np.float64        # default unchanged
    assert _norm_1d([0.5], np.float32).dtype == np.float32

    svc = SweepService()
    for want in ("float32", "float64"):
        req = IntegrationRequest(family="gaussian", params=[0.3, 0.5],
                                 dtype=want)
        _, params, cfg = svc._resolve(req)
        # The normalized array the family builder receives carries the
        # REQUEST's dtype, not a hardwired float64.  (What the builder then
        # does with it is the family's own contract.)
        assert params.dtype == np.dtype(want), (want, params.dtype)

    # ...and the request's accum_dtype lands in the plan's PrecisionPolicy.
    req = IntegrationRequest(family="gaussian", params=[0.3],
                             accum_dtype="float64")
    _, _, cfg = svc._resolve(req)
    assert cfg.execution.precision.accum_dtype == "float64"
    assert req.compat_key() != dataclasses.replace(
        req, accum_dtype=None).compat_key()


def test_sharded_fill_subtracts_psummed_compensation(monkeypatch):
    """Regression: the sharded combination psummed the Kahan accumulators
    and threw the compensations away.  Drive `make_sharded_fill` with a
    fake backend fill producing a known (part, comp) pair: the combined
    result must be part - comp (the corrected total), not part — and the
    builder must have asked the backend for the compensation at all."""
    from repro.core.fill import FillResult

    ig = make_cosine(dim=2)
    rc = _cfg("ref").resolve(ig.dim)
    n_cubes = rc.nstrat**ig.dim
    part = FillResult(jnp.full((ig.dim, rc.ninc), 2.0),
                      jnp.full((ig.dim, rc.ninc), 8.0),
                      jnp.full((n_cubes,), 4.0), jnp.full((n_cubes,), 6.0))
    comp = jax.tree.map(lambda x: jnp.full_like(x, 0.25), part)
    seen = {}

    def fake_bind_fill(rcfg, backend=None, **overrides):
        seen.update(overrides)
        return lambda e, nh, k, integ, **kw: (part, comp)

    from repro.engine import sharding as sharding_mod
    monkeypatch.setattr(sharding_mod.backends_mod, "bind_fill",
                        fake_bind_fill)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    fill = sharding_mod.make_sharded_fill(mesh, ("data",), rc)
    assert seen.get("kahan") and seen.get("return_comp"), seen
    got = fill(jnp.zeros((ig.dim, rc.ninc + 1)), jnp.ones((n_cubes,)),
               jax.random.PRNGKey(0), ig)
    want = jax.tree.map(jnp.subtract, part, comp)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_kahan_shard_invariance_subprocess():
    """Regression: with the compensation carried through the psum, the
    sharded fill on ANY device count stays within a few ulps of the f64
    ground truth — and 1/2/4-shard results agree with each other at that
    floor.  Run under 4 forced host devices in a subprocess so the device
    count never leaks into this process."""
    worker = os.path.join(os.path.dirname(__file__), "_precision_worker.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, worker], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL_OK" in out.stdout, out.stdout


def test_checkpoint_restore_across_x64_flip(tmp_path):
    """Regression: a checkpoint written under JAX_ENABLE_X64=1 (f64 leaves)
    must restore into an x64-off process — leaves are cast to the
    template's dtypes instead of crashing the donated-buffer resume."""
    from repro.dist import checkpoint as CK
    tree64 = {"edges": np.linspace(0.0, 1.0, 9, dtype=np.float64),
              "it": np.int64(4)}
    p = str(tmp_path / "c.npz")
    CK.save(p, tree64, step=4)
    like = {"edges": jnp.zeros(9, jnp.float32), "it": jnp.array(0, jnp.int32)}
    back, step, _ = CK.restore(p, like)
    assert step == 4
    assert back["edges"].dtype == jnp.float32
    assert back["it"].dtype == jnp.int32
    np.testing.assert_allclose(np.asarray(back["edges"]),
                               tree64["edges"].astype(np.float32))


def test_checkpoint_restore_rejects_kind_mismatch(tmp_path):
    """A float-vs-int flip is NOT an x64 flip: refuse with an error naming
    the offending leaf rather than silently casting across kinds."""
    from repro.dist import checkpoint as CK
    p = str(tmp_path / "c.npz")
    CK.save(p, {"a": np.float32(1.5), "b": np.arange(3, dtype=np.int32)},
            step=0)
    like = {"a": jnp.array(0.0, jnp.float32), "b": jnp.zeros(3, jnp.float32)}
    with pytest.raises(ValueError, match="different kinds") as ei:
        CK.restore(p, like)
    assert "'b'" in str(ei.value) or "b" in str(ei.value)


def test_bench_gates_never_pair_across_precision_policies():
    """Regression guard for --gate-abs/--gate-run/--gate-fill: a widened-f64
    timing must never be compared against an f32 (or legacy, un-stamped)
    timing."""
    from benchmarks.run import gate_abs, gate_fill, gate_run

    def row(name, us, accum=None, **kw):
        r = dict(name=name, us_per_call=us, interpret=False, **kw)
        if accum is not None:
            r["accum_dtype"] = accum
        return r

    # gate_fill: a slower f64 fused row is SKIPPED against its f32 twin...
    rows = [row("d4/fill_pallas", 100.0),
            row("d4/fill_fused", 900.0, accum="float64")]
    assert gate_fill(rows) == []
    # ...but fails once both rows share the policy.
    rows[1] = row("d4/fill_fused", 900.0)
    assert gate_fill(rows) != []

    # gate_run: mismatched policies leave no measurable pair.
    rows = [row("run/autotune/s/default", 100.0),
            row("run/autotune/s/autotuned", 900.0, accum="float64")]
    fails = gate_run(rows)
    assert any("nothing to check" in f for f in fails), fails

    # gate_abs: a legacy prior (no accum_dtype stamp => f32) never gates a
    # widened current row — skipped, not failed.
    cur = [row("fill/x", 1000.0, accum="float64", backend="pallas-fused",
               device_kind="tpu-v4")]
    prior = [row("fill/x", 100.0, backend="pallas-fused",
                 device_kind="tpu-v4")]
    fails, checked, skipped = gate_abs(cur, prior)
    assert fails == [] and checked == 0 and skipped == 1
    # Same row stamped f32 pairs normally and trips the gate.
    cur[0] = row("fill/x", 1000.0, backend="pallas-fused",
                 device_kind="tpu-v4")
    fails, checked, _ = gate_abs(cur, prior)
    assert checked == 1 and fails != []
