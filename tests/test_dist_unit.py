"""In-process unit tests for repro.dist: chunk-range partition math and
CheckpointManager edge behavior. The full 8-device integration suite lives in
test_distributed.py (subprocess); these run on the single real CPU device."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.integrator import VegasConfig
from repro.dist import checkpoint as CK
from repro.dist.sharded_fill import shard_chunk_range


# --- chunk-range partition math ---------------------------------------------

@pytest.mark.parametrize("total,n_shards", [
    (34, 8),   # uneven: ceil(34/8)=5, last shard range is all padding
    (34, 2), (34, 1), (7, 8),  # more shards than chunks
    (64, 8),   # exact division
    (1, 3),
])
def test_ranges_disjoint_and_cover(total, n_shards):
    covered = set()
    counts = set()
    for k in range(n_shards):
        start, count = shard_chunk_range(total, k, n_shards)
        counts.add(count)
        rng = set(range(start, start + count))
        assert not (rng & covered), "shard ranges overlap"
        covered |= rng
    # Same static per-shard count everywhere (identical compiled program).
    assert len(counts) == 1
    # Union covers every real chunk; anything extra is masked padding, and
    # there is less than one padding chunk per shard (ceil division).
    assert covered >= set(range(total))
    assert len(covered) - total < n_shards


def test_device_count_changes_grouping_not_coverage():
    total = 34
    for n in (1, 2, 4, 8, 16):
        real = set()
        for k in range(n):
            start, count = shard_chunk_range(total, k, n)
            real |= set(range(start, min(start + count, total)))
        assert real == set(range(total)), n


def test_resolve_pads_n_cap_to_chunk_multiple():
    cfg = VegasConfig(neval=40_000, ninc=64, chunk=2048)
    rc = cfg.resolve(4)
    assert rc.n_cap % rc.chunk == 0
    assert rc.n_cap >= rc.neval  # capacity never shrinks below the target
    # The padded tail is what overflow-bucket masking (DESIGN.md C2) absorbs.
    assert rc.n_cap - (rc.neval + 2 * rc.n_cubes) < rc.chunk


# --- CheckpointManager edge behavior ----------------------------------------

def test_restore_latest_empty_dir_returns_none(tmp_path):
    """Cold start: no checkpoints is not an error (resume paths restart
    fresh iff restore_latest returns something)."""
    mgr = CK.CheckpointManager(str(tmp_path), keep=2)
    assert mgr.restore_latest({"x": jnp.zeros((2,))}) is None
    # .tmp leftovers from a torn write still count as "no checkpoints".
    (tmp_path / "ckpt_0.npz.tmp").write_bytes(b"garbage")
    assert mgr.restore_latest({"x": jnp.zeros((2,))}) is None


def test_restore_latest_skips_corrupt_file(tmp_path):
    mgr = CK.CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"x": jnp.array([1.0])})
    # A later checkpoint that is complete-looking but unreadable garbage.
    (tmp_path / "ckpt_2.npz").write_bytes(b"not a zip file")
    got, step, _ = mgr.restore_latest({"x": jnp.zeros((1,))})
    assert step == 1 and float(got["x"][0]) == 1.0


def test_restore_latest_all_corrupt_raises(tmp_path):
    mgr = CK.CheckpointManager(str(tmp_path), keep=3)
    (tmp_path / "ckpt_0.npz").write_bytes(b"garbage")
    with pytest.raises(FileNotFoundError):
        mgr.restore_latest({"x": jnp.zeros((1,))})


def test_restore_wrong_structure_is_corrupt(tmp_path):
    """Leaf-count mismatch against the template counts as unreadable."""
    mgr = CK.CheckpointManager(str(tmp_path), keep=3)
    mgr.save(0, {"x": jnp.zeros((1,))})
    with pytest.raises(FileNotFoundError):
        mgr.restore_latest({"x": jnp.zeros((1,)), "y": jnp.zeros((1,))})


def test_manager_retention_never_removes_newest(tmp_path):
    mgr = CK.CheckpointManager(str(tmp_path), keep=1)
    for s in (2, 7, 5):  # out-of-order saves: retention is by step, not mtime
        mgr.save(s, {"x": jnp.array([float(s)])})
    assert os.listdir(tmp_path) == ["ckpt_7.npz"]
    got, step, _ = mgr.restore_latest({"x": jnp.zeros((1,))})
    assert step == 7 and float(got["x"][0]) == 7.0


def test_meta_roundtrip_and_defaults(tmp_path):
    p = str(tmp_path / "c.npz")
    CK.save(p, [jnp.arange(3)], step=11)
    back, step, meta = CK.restore(p, [jnp.zeros((3,), jnp.int32)])
    assert step == 11 and meta == {}
    np.testing.assert_array_equal(np.asarray(back[0]), np.arange(3))
