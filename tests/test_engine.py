"""Unified execution engine (ISSUE 4): config split + deprecation shim,
capability-declaring backend registry, plan validation (loud PlanError
instead of tracer failures), and engine-vs-legacy path equivalence.

The multi-device half of the acceptance criteria — a sharded AND batched
run as one jitted program — lives in tests/_dist_worker.py (check 6), which
runs under 8 forced host devices."""

import dataclasses

import jax
import numpy as np
import pytest

import repro.engine as E
from repro.batch import run_batch, run_serial
from repro.batch.family import make_gaussian_family
from repro.core import VegasConfig, run
from repro.core import integrands as igs
from repro.launch.mesh import make_local_mesh

FAST = VegasConfig(neval=8_000, max_it=4, skip=1, ninc=32, chunk=2048)
KEY = jax.random.PRNGKey(5)


# --- VegasConfig split + deprecation shim ------------------------------------

def test_config_splits_algorithm_from_execution():
    cfg = VegasConfig()
    assert cfg.execution == E.ExecutionConfig()
    assert cfg.backend == "ref" and cfg.interpret is None and cfg.tile is None
    # algorithm fields are real dataclass fields; execution knobs are not
    names = {f.name for f in dataclasses.fields(cfg)}
    assert "backend" not in names and "execution" in names


def test_legacy_flat_fields_warn_and_fold_into_execution():
    with pytest.warns(DeprecationWarning, match="execution knobs moved"):
        cfg = VegasConfig(backend="pallas", fused_cubes=True, tile=64,
                          interpret=True)
    assert cfg.execution.backend == "pallas-fused"
    assert cfg.backend == "pallas-fused" and cfg.fused_cubes
    assert cfg.tile == 64 and cfg.interpret is True
    with pytest.warns(DeprecationWarning):
        cfg2 = VegasConfig(backend="pallas", fused_cubes=False)
    assert cfg2.execution.backend == "pallas" and not cfg2.fused_cubes
    with pytest.warns(DeprecationWarning):
        cfg3 = VegasConfig(backend="ref")
    assert cfg3.execution.backend == "ref"


def test_legacy_kwarg_never_upgrades_an_explicit_backend_choice():
    """Mixing one legacy kwarg (interpret) with an explicitly chosen
    registry backend must not remap 'pallas' (P-V2) to 'pallas-fused': the
    legacy fused default applies only when backend/fused_cubes themselves
    came in through the flat spelling."""
    with pytest.warns(DeprecationWarning):
        cfg = VegasConfig(interpret=True,
                          execution=E.ExecutionConfig(backend="pallas"))
    assert cfg.execution.backend == "pallas"
    assert cfg.interpret is True
    # fused_cubes=False alone downgrades a fused execution config
    with pytest.warns(DeprecationWarning):
        cfg2 = VegasConfig(fused_cubes=False,
                           execution=E.ExecutionConfig(backend="pallas-fused"))
    assert cfg2.execution.backend == "pallas"


def test_plan_accepts_any_dtype_spelling():
    """Every spelling jnp.dtype() accepts must validate like its canonical
    name (callers pre-engine passed np/jnp dtypes, not just strings)."""
    import jax.numpy as jnp
    for spelling in ("float32", "f4", np.float32, jnp.float32):
        E.make_plan(IG, dataclasses.replace(FAST, dtype=spelling))
    with pytest.raises(E.PlanError):
        E.make_plan(IG, dataclasses.replace(FAST, dtype=np.float64),
                    execution=E.ExecutionConfig(backend="pallas-fused"))


def test_config_rejects_unknown_kwargs_and_duplicates():
    with pytest.raises(TypeError, match="bogus"):
        VegasConfig(bogus=1)
    with pytest.raises(TypeError, match="duplicate"):
        VegasConfig(10_000, neval=20_000)


def test_dataclasses_replace_and_with_execution():
    cfg = dataclasses.replace(FAST, neval=123_000)
    assert cfg.neval == 123_000 and cfg.ninc == FAST.ninc
    assert cfg.execution == FAST.execution
    ex = E.ExecutionConfig(backend="pallas-fused", interpret=True)
    cfg2 = FAST.with_execution(ex)
    assert cfg2.execution is ex and cfg2.neval == FAST.neval


def test_shim_runs_identically_to_execution_config():
    """The legacy flat spelling and the ExecutionConfig spelling are the
    same program: bit-identical results."""
    ig = igs.make_cosine(dim=2)
    kw = dict(neval=6_000, max_it=3, ninc=16, chunk=2048)
    with pytest.warns(DeprecationWarning):
        legacy = VegasConfig(backend="pallas", interpret=True, **kw)
    new = VegasConfig(execution=E.ExecutionConfig(backend="pallas-fused",
                                                  interpret=True), **kw)
    r1 = run(ig, legacy, key=KEY)
    r2 = run(ig, new, key=KEY)
    assert r1.mean == r2.mean and r1.sdev == r2.sdev


# --- backend registry --------------------------------------------------------

def test_registry_declares_capability_matrix():
    assert set(E.available()) >= {"ref", "pallas", "pallas-fused"}
    ref = E.get_backend("ref")
    fused = E.get_backend("pallas-fused")
    assert ref.supports("shardable") and ref.supports("vmappable")
    assert fused.supports("in-kernel-rng") and not ref.supports("in-kernel-rng")
    assert fused.dtypes == ("float32",)
    text = E.capability_matrix()
    for name in E.available():
        assert name in text


def test_register_rejects_duplicates_and_unknown_capabilities():
    spec = E.get_backend("ref")
    with pytest.raises(ValueError, match="already registered"):
        E.register(spec)
    with pytest.raises(ValueError, match="unknown capabilities"):
        E.register(dataclasses.replace(
            spec, name="exotic", capabilities=frozenset({"warp-speed"})))
    assert "exotic" not in E.available()


# --- plan validation: loud PlanError, never a tracer failure -----------------

IG = igs.make_cosine(dim=2)


def test_plan_rejects_unknown_backend():
    with pytest.raises(E.PlanError, match="unknown fill backend.*registered"):
        E.make_plan(IG, FAST, execution=E.ExecutionConfig(backend="cuda"))


def test_plan_rejects_knobs_the_backend_does_not_declare():
    with pytest.raises(E.PlanError, match="tile.*not a knob.*'ref'"):
        E.make_plan(IG, FAST, execution=E.ExecutionConfig(tile=128))
    with pytest.raises(E.PlanError, match="interpret.*not a knob"):
        E.make_plan(IG, FAST, execution=E.ExecutionConfig(interpret=True))


def test_plan_rejects_unsupported_dtype():
    cfg = dataclasses.replace(FAST, dtype="float64")
    with pytest.raises(E.PlanError, match="float32.*float64"):
        E.make_plan(IG, cfg,
                    execution=E.ExecutionConfig(backend="pallas-fused"))
    # the oracle declares f64 support: same plan, no error
    E.make_plan(IG, cfg, execution=E.ExecutionConfig(backend="ref"))


def test_plan_rejects_vmap_of_a_plain_integrand():
    with pytest.raises(E.PlanError, match="IntegrandFamily"):
        E.make_plan(IG, FAST, execution=E.ExecutionConfig(batch="vmap"))
    with pytest.raises(E.PlanError, match="batch='sideways'"):
        E.make_plan(IG, FAST, execution=E.ExecutionConfig(batch="sideways"))


def test_plan_rejects_inconsistent_sharding():
    with pytest.raises(E.PlanError, match="without a mesh"):
        E.make_plan(IG, FAST,
                    execution=E.ExecutionConfig(shard_axes=("data",)))
    mesh = make_local_mesh()
    with pytest.raises(E.PlanError, match="not in mesh axes"):
        E.make_plan(IG, FAST, execution=E.ExecutionConfig(
            mesh=mesh, shard_axes=("model",)))


def test_plan_rejects_checkpointing_a_family():
    fam = make_gaussian_family(np.array([0.3, 0.7]))
    with pytest.raises(E.PlanError, match="single-scenario"):
        E.make_plan(fam, FAST, execution=E.ExecutionConfig(
            checkpoint=E.CheckpointPolicy(directory="/tmp/x")))
    with pytest.raises(E.PlanError, match="directory or a callback"):
        E.make_plan(IG, FAST, execution=E.ExecutionConfig(
            checkpoint=E.CheckpointPolicy()))


def test_plan_describe_names_every_axis():
    fam = make_gaussian_family(np.array([0.3, 0.7]))
    plan = E.make_plan(fam, FAST, execution=E.ExecutionConfig(
        backend="pallas-fused", interpret=True))
    text = plan.describe()
    assert "pallas-fused" in text and "vmap B=2" in text
    assert "fori_loop" in text and "in-kernel-rng" in text


# --- executor composition ----------------------------------------------------

def test_engine_single_scenario_matches_core_run():
    plan = E.make_plan(IG, FAST)
    r_engine = E.execute(plan, key=KEY)
    r_run = run(IG, FAST, key=KEY)
    assert r_engine.mean == r_run.mean and r_engine.sdev == r_run.sdev


def test_single_device_mesh_plan_matches_unsharded():
    """A 1-device mesh resolves to n_shards=1 and must be the identical
    program (no shard_map wrapping, no kahan difference)."""
    mesh = make_local_mesh()
    plan = E.make_plan(IG, FAST, execution=E.ExecutionConfig(mesh=mesh))
    assert plan.n_shards == jax.device_count()
    if plan.n_shards == 1:
        r = E.execute(plan, key=KEY)
        assert r.mean == run(IG, FAST, key=KEY).mean


def test_family_serial_mode_matches_run_serial_bitwise():
    fam = make_gaussian_family(np.array([0.25, 0.75]))
    plan = E.make_plan(fam, FAST,
                       execution=E.ExecutionConfig(batch="serial"))
    assert plan.is_family and not plan.batched
    outs = E.execute(plan, key=KEY)
    base = run_serial(fam, FAST, key=KEY)
    assert [o.mean for o in outs] == [b.mean for b in base]


def test_run_batch_rejects_a_serial_plan():
    fam = make_gaussian_family(np.array([0.25, 0.75]))
    with pytest.raises(ValueError, match="vmapped path"):
        run_batch(fam, FAST, execution=E.ExecutionConfig(batch="serial"))


def test_family_rejects_state_resume():
    fam = make_gaussian_family(np.array([0.25, 0.75]))
    plan = E.make_plan(fam, FAST)
    st = run(IG, FAST, key=KEY).state
    with pytest.raises(ValueError, match="single-scenario"):
        E.execute(plan, key=KEY, state=st)


def test_checkpoint_policy_writes_and_resumes(tmp_path):
    """The checkpoint execution axis: a policy forces the host loop, writes
    retained checkpoints, and the restored state resumes to the same answer
    as the uninterrupted run."""
    from repro.dist.checkpoint import CheckpointManager
    cfg_half = dataclasses.replace(FAST, max_it=2).with_execution(
        E.ExecutionConfig(checkpoint=E.CheckpointPolicy(
            directory=str(tmp_path), keep=2)))
    run(IG, cfg_half, key=KEY)
    files = sorted(p.name for p in tmp_path.iterdir())
    assert files == ["ckpt_0.npz", "ckpt_1.npz"]

    full = run(IG, FAST, key=KEY)
    mgr = CheckpointManager(str(tmp_path))
    restored, step, _ = mgr.restore_latest(full.state)
    resumed = run(IG, FAST, key=KEY, state=restored)
    assert resumed.mean == pytest.approx(full.mean, rel=1e-6)


def test_checkpoint_policy_every_throttles(tmp_path):
    cfg = dataclasses.replace(FAST, max_it=4).with_execution(
        E.ExecutionConfig(checkpoint=E.CheckpointPolicy(
            directory=str(tmp_path), keep=10, every=2)))
    run(IG, cfg, key=KEY)
    files = sorted(p.name for p in tmp_path.iterdir())
    assert files == ["ckpt_1.npz", "ckpt_3.npz"]


def test_run_batch_through_engine_matches_serial():
    """The adapter chain (run_batch -> make_plan -> execute) preserves the
    batched-vs-serial stream parity contract."""
    fam = make_gaussian_family(np.linspace(0.3, 0.7, 3))
    batched = run_batch(fam, FAST, key=KEY)
    serial = run_serial(fam, FAST, key=KEY)
    for b in range(3):
        comb = float(np.hypot(batched.sdev[b], serial[b].sdev))
        assert abs(float(batched.mean[b]) - serial[b].mean) < 3 * comb
