import os

# Tests and benches must see the single real CPU device (the 512-device
# override lives ONLY at the top of launch/dryrun.py, per the dry-run spec).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
