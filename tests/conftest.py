import os

# Tests and benches must see the single real CPU device (multi-device suites
# force extra host devices in subprocesses only, tests/_dist_worker.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

# The RNG contract (DESIGN.md C5 / §7) claims bit-exact streams under BOTH
# threefry counter layouts; CI runs the tier-1 suite twice, flipping this
# env var, so neither layout is the untested one.
_partitionable = os.environ.get("REPRO_THREEFRY_PARTITIONABLE", "1") != "0"
jax.config.update("jax_threefry_partitionable", _partitionable)
