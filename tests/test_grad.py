"""Gradient conformance suite (§11): is the pathwise gradient CORRECT?

Four layers of evidence, mirroring how the estimator is built:

  * **frozen-map exactness** — with the map/stratification/eval-key held
    fixed, the custom-AD gradient must match a central finite difference of
    the very same deterministic program to float precision (no statistics
    involved: the eval pass is a pure function of its inputs);
  * **full-run conformance** — ``jax.grad`` of the whole two-phase run
    (adapt included) vs a central FD of the run itself, within 3 combined
    sigma on all three paper families (gaussian peak / ridge / asian);
  * **gradient pulls** — over N seeded replicas (one vmapped program), the
    gradient pulls ``(g - dI/dtheta_true) / sigma_g`` must be ~ N(0, 1):
    the ``with_sdev`` error bars mean what they claim (same binomial
    coverage oracle as tests/test_statistical.py, same REPRO_STATS_SEED
    CI matrix);
  * **structural identities** — zero gradient for parameter-independent
    integrands, vjp == jvp flavor, vmapped-sweep == stacked per-scenario
    grads, ref == pallas backend pairing, and the `combine_results`
    NaN-safety regression for differentiated sentinel rows.
"""

import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.batch.family import (make_asian_family, make_asian_greeks_family,
                                make_gaussian_family, make_ridge_family)
from repro.core import VegasConfig
from repro.core import integrator as core
from repro.engine import ExecutionConfig, GradPolicy, execute, make_plan
from repro.grad import differentiable, directional_moments, execute_grad
from repro.grad.api import BatchGradResult, GradResult

SEED = int(os.environ.get("REPRO_STATS_SEED", "0"))
KEY = jax.random.PRNGKey(SEED)

#: Small but honest config: enough evals that the eval pass's sigma is a
#: usable yardstick, small enough that the whole module runs in seconds.
CFG = VegasConfig(neval=6_000, max_it=8, skip=4, ninc=64, chunk=2048)

DIM = 3
UNIT = ((0.0,) * DIM, (1.0,) * DIM)


def _gaussian_fn(sigma=0.2):
    norm = 1.0 / (2.0 * math.pi * sigma**2) ** (DIM / 2.0)

    def fn(mu, x):
        return norm * jnp.exp(-jnp.sum((x - mu) ** 2, -1) / (2.0 * sigma**2))
    return fn


def _gaussian_dI_dmu(mu, sigma=0.2, dim=DIM):
    """Analytic d/dmu of the unit-cube gaussian-peak integral (all dims
    share the peak location mu): I = A(mu)^dim."""
    s2 = sigma * math.sqrt(2.0)
    a = 0.5 * (math.erf((1.0 - mu) / s2) + math.erf(mu / s2))
    da = (math.exp(-((mu / s2) ** 2))
          - math.exp(-(((1.0 - mu) / s2) ** 2))) / (s2 * math.sqrt(math.pi))
    return dim * a ** (dim - 1) * da


# --- frozen-map exactness ----------------------------------------------------

def _scalar_families():
    """(name, fn, p0, tangent, bounds, eps) — each reduced to a scalar
    directional parameter t around p0 so one FD covers vector params too."""
    ridge = make_ridge_family(np.array([[0.6, 0.8, 1.0]]), dim=3, n_peaks=8)
    asian = make_asian_family(np.array([100.0]), n_steps=4)
    v = jnp.asarray([0.5, -0.3, 0.8], jnp.float32)
    return [
        ("gaussian", _gaussian_fn(), jnp.float32(0.15), jnp.float32(1.0),
         UNIT, 3e-3),
        ("ridge", ridge.fn, jnp.asarray([0.6, 0.8, 1.0], jnp.float32), v,
         (ridge.lower, ridge.upper), 3e-3),
        ("asian", asian.fn, jnp.float32(100.0), jnp.float32(1.0),
         (asian.lower, asian.upper), 0.5),
    ]


@pytest.mark.parametrize("name,fn,p0,tv,bounds,eps",
                         _scalar_families(),
                         ids=["gaussian", "ridge", "asian"])
def test_frozen_map_grad_matches_fd(name, fn, p0, tv, bounds, eps):
    """With (edges, n_h, ekey) pinned, `diff` is a deterministic function —
    its custom-free jax.grad and a central FD of it must agree to float
    precision, independent of any Monte Carlo statistics."""
    lower, upper = bounds
    est = differentiable(fn, len(lower), lower, upper, CFG, name=name)
    prog = est.program
    dt = jnp.dtype(est.plan.cfg.dtype)
    l0, u0 = jnp.asarray(lower, dt), jnp.asarray(upper, dt)
    edges, n_h, _ = jax.jit(prog.adapt)(p0, l0, u0, KEY)
    ekey = core.eval_key(KEY, est.plan.cfg)

    def along(t):
        return prog.diff(p0 + t * tv, l0, u0, edges, n_h, ekey)[0]

    g = float(jax.grad(along)(jnp.zeros((), dt)))
    fd = float((along(jnp.asarray(eps, dt)) - along(jnp.asarray(-eps, dt)))
               / (2.0 * eps))
    assert np.isclose(g, fd, rtol=2e-2, atol=5e-4), (name, g, fd)


# --- full-run conformance (3 combined sigma) ---------------------------------

@pytest.mark.parametrize("name,fn,p0,tv,bounds,eps",
                         _scalar_families(),
                         ids=["gaussian", "ridge", "asian"])
def test_full_run_grad_matches_fd_three_sigma(name, fn, p0, tv, bounds, eps):
    """jax.grad of the FULL run (adapt + eval) vs central FD of the full
    run.  The FD re-adapts at theta +- eps, so both its eval noise and the
    map-shift noise enter; the bound is 3 x the combined sigma of the
    gradient estimate and the FD quotient (conservative: common random
    numbers correlate the two FD runs, shrinking the true spread)."""
    lower, upper = bounds
    est = differentiable(fn, len(lower), lower, upper, CFG, name=name)
    rcfg = est.plan.cfg
    dt = jnp.dtype(rcfg.dtype)
    l0, u0 = jnp.asarray(lower, dt), jnp.asarray(upper, dt)

    def along(t):
        return est.pair(jax.tree.map(lambda p: p + t * tv, p0),
                        l0, u0, KEY)
    g = float(jax.grad(lambda t: along(t)[0])(jnp.zeros((), dt)))

    mp, s2p = along(jnp.asarray(eps, dt))
    mm, s2m = along(jnp.asarray(-eps, dt))
    fd = float(mp - mm) / (2.0 * eps)
    sigma_fd = math.sqrt(float(s2p) + float(s2m)) / (2.0 * eps)

    # The gradient's own error bar: the derivative integrand through the
    # same frozen map/eval stream the grad used.
    from repro.engine import backends as backends_mod
    prog = est.program
    edges, n_h, _ = jax.jit(prog.adapt)(p0, l0, u0, KEY)
    _, g_sigma2 = directional_moments(
        fn, p0, tv, l0, u0, edges, n_h, core.eval_key(KEY, rcfg), rcfg,
        backends_mod.bind_fill(rcfg, backend="ref"))
    combined = math.hypot(math.sqrt(float(g_sigma2)), sigma_fd)
    assert abs(g - fd) <= 3.0 * combined + 1e-4, (
        f"{name}: grad {g:+.5g} vs FD {fd:+.5g} "
        f"({abs(g - fd) / max(combined, 1e-30):.2f} combined sigma)")


def test_full_run_grad_matches_analytic_gaussian():
    """Against the exact erf-product derivative — no FD noise at all."""
    fn = _gaussian_fn()
    est = differentiable(fn, DIM, *UNIT, CFG, name="gaussian")
    rcfg = est.plan.cfg
    mu0 = jnp.float32(0.15)
    g = float(jax.grad(lambda m: est(m, KEY))(mu0))
    truth = _gaussian_dI_dmu(0.15)

    from repro.engine import backends as backends_mod
    prog = est.program
    dt = jnp.dtype(rcfg.dtype)
    l0, u0 = jnp.zeros(DIM, dt), jnp.ones(DIM, dt)
    edges, n_h, _ = jax.jit(prog.adapt)(mu0, l0, u0, KEY)
    _, g_sigma2 = directional_moments(
        fn, mu0, jnp.float32(1.0), l0, u0, edges, n_h,
        core.eval_key(KEY, rcfg), rcfg,
        backends_mod.bind_fill(rcfg, backend="ref"))
    sigma_g = math.sqrt(float(g_sigma2))
    assert abs(g - truth) <= 4.0 * sigma_g + 1e-4, (g, truth, sigma_g)


# --- gradient pull distribution (the with_sdev error bars are honest) --------

N_RUNS = 50
MIN_COVERED = 42  # binomial floor at p=0.95, n=50 (test_statistical.py)


def test_grad_pull_distribution():
    """N seeded replicas of d(gaussian integral)/d(mu), one vmapped grad
    program: pulls against the analytic derivative, scaled by each
    replica's own derivative-integrand sigma, must be ~ N(0, 1)."""
    fam = make_gaussian_family(np.full(N_RUNS, 0.15), dim=DIM, sigma=0.2)
    cfg = CFG.with_execution(ExecutionConfig(grad=GradPolicy()))
    plan = make_plan(fam, cfg)
    res = execute(plan, key=KEY)
    assert isinstance(res, BatchGradResult) and res.grad_sdev is not None

    g = np.asarray(jax.tree.leaves(res.grad)[0])          # (N,)
    sg = np.asarray(jax.tree.leaves(res.grad_sdev)[0])    # (N,)
    truth = _gaussian_dI_dmu(0.15)
    pulls = (g - truth) / sg

    covered = int(np.sum(np.abs(pulls) <= 1.96))
    assert covered >= MIN_COVERED, (
        f"grad pulls: only {covered}/{N_RUNS} within 1.96 sigma — "
        f"grad_sdev underestimates the gradient error")
    assert abs(np.mean(pulls)) <= 4.2 / math.sqrt(N_RUNS), (
        f"grad pull mean {np.mean(pulls):+.3f} — biased gradient estimator")
    assert 0.55 <= np.std(pulls) <= 1.55, (
        f"grad pull std {np.std(pulls):.3f} — mis-scaled grad_sdev")


# --- structural identities ---------------------------------------------------

def test_zero_gradient_for_parameter_independent_integrand():
    """fn ignores params => the cotangent never reaches them: exact zeros,
    not merely small ones."""
    fn = lambda p, x: jnp.prod(jnp.sin(math.pi * x) * math.pi / 2.0, -1)
    est = differentiable(fn, 2, (0.0, 0.0), (1.0, 1.0), CFG, name="sine")
    p = {"a": jnp.float32(0.3), "b": jnp.arange(3, dtype=jnp.float32)}
    g = jax.grad(lambda q: est(q, KEY))(p)
    for leaf in jax.tree.leaves(g):
        assert np.all(np.asarray(leaf) == 0.0), g


def test_jvp_flavor_matches_vjp_flavor():
    fn = _gaussian_fn()
    kw = dict(cfg=CFG, name="gaussian")
    est_v = differentiable(fn, DIM, *UNIT, **kw)
    est_j = differentiable(fn, DIM, *UNIT, ad="jvp", **kw)
    mu0 = jnp.float32(0.3)
    gv = jax.grad(lambda m: est_v(m, KEY))(mu0)
    gj = jax.grad(lambda m: est_j(m, KEY))(mu0)
    # Same program on both sides of the custom-AD boundary: bitwise.
    assert np.asarray(gv).tobytes() == np.asarray(gj).tobytes(), (gv, gj)
    # Forward mode directly: same estimator, but the tangent accumulates
    # alongside the primal in a different f32 summation order than the
    # transposed cotangent — close, not bitwise.
    _, tj = jax.jvp(lambda m: est_j(m, KEY), (mu0,), (jnp.float32(1.0),))
    assert np.isclose(float(gv), float(tj), rtol=3e-2), (gv, tj)


def test_vmapped_sweep_grad_matches_stacked():
    """grad-of-vmapped-sweep == stacked per-scenario grads: summing the
    vmapped estimates and differentiating must equal vmapping the
    per-scenario grad (bitwise — same traced program, scenarios are
    independent so the sum's cotangent fans out as identity), and both must
    match serially-stacked single-scenario grads stream-for-stream."""
    from repro.batch.engine import scenario_keys
    asian = make_asian_family(np.array([90.0, 100.0, 110.0]), n_steps=4)
    est = differentiable(asian.fn, asian.dim, asian.lower, asian.upper, CFG,
                         name=asian.name)
    strikes = jnp.asarray([90.0, 100.0, 110.0], jnp.float32)
    keys = scenario_keys(KEY, 3)

    per = jax.vmap(lambda s, k: jax.grad(lambda p: est(p, k))(s))
    g_vmapped = per(strikes, keys)
    g_sum = jax.grad(lambda s: jnp.sum(jax.vmap(
        lambda sb, kb: est(sb, kb))(s, keys)))(strikes)
    assert (np.asarray(g_vmapped).tobytes()
            == np.asarray(g_sum).tobytes()), (g_vmapped, g_sum)

    g_serial = np.stack([
        np.asarray(jax.grad(lambda p: est(p, jax.random.fold_in(KEY, b)))(
            strikes[b])) for b in range(3)])
    np.testing.assert_allclose(np.asarray(g_vmapped), g_serial,
                               rtol=2e-4, atol=1e-6)


def test_pallas_backend_grad_pairs_with_ref():
    """backend='pallas' (value from the kernel, cotangent through the ref
    formulation on the same chunk-keyed stream) must reproduce the ref
    backend's gradient — the grad-pathwise capability pairing."""
    fn = _gaussian_fn()
    tiny = VegasConfig(neval=2_000, max_it=3, ninc=32, chunk=1024)
    mu0 = jnp.float32(0.3)
    grads = {}
    for backend in ("ref", "pallas"):
        est = differentiable(fn, DIM, *UNIT, tiny,
                             execution=ExecutionConfig(backend=backend),
                             name="gaussian")
        grads[backend] = np.asarray(jax.grad(lambda m: est(m, KEY))(mu0))
    np.testing.assert_allclose(grads["pallas"], grads["ref"],
                               rtol=1e-4, atol=1e-7)


def test_grad_sdev_directional_matches_vjp():
    """The derivative-integrand pass (with_sdev channel) is the SAME
    estimator the vjp computes — its mean must match the vjp gradient on
    identical sample paths."""
    from repro.engine import backends as backends_mod
    fn = _gaussian_fn()
    est = differentiable(fn, DIM, *UNIT, CFG, name="gaussian")
    rcfg = est.plan.cfg
    prog = est.program
    dt = jnp.dtype(rcfg.dtype)
    mu0 = jnp.float32(0.3)
    l0, u0 = jnp.zeros(DIM, dt), jnp.ones(DIM, dt)
    edges, n_h, _ = jax.jit(prog.adapt)(mu0, l0, u0, KEY)
    ekey = core.eval_key(KEY, rcfg)

    _, vjp_fn = jax.vjp(lambda p: prog.diff(p, l0, u0, edges, n_h, ekey),
                        mu0)
    (gp,) = vjp_fn((jnp.float32(1.0), jnp.float32(0.0)))
    g_dir, _ = directional_moments(
        fn, mu0, jnp.float32(1.0), l0, u0, edges, n_h, ekey, rcfg,
        backends_mod.bind_fill(rcfg, backend="ref"))
    assert np.isclose(float(gp), float(g_dir), rtol=1e-4), (gp, g_dir)


# --- engine routing ----------------------------------------------------------

def test_execute_grad_single_bounds_sensitivities():
    """The engine route for a plain Integrand: GradResult with boundary
    sensitivities; on a constant integrand they obey the exact product
    rule d(est)/d(upper_j) = est / (upper_j - lower_j)."""
    from repro.core.integrands import Integrand
    ig = Integrand("const", 2, lambda x: jnp.full(x.shape[:-1], 2.5),
                   (0.0, 0.0), (2.0, 1.0), target=5.0)
    cfg = VegasConfig(neval=2_000, max_it=3, ninc=32, chunk=1024,
                      execution=ExecutionConfig(grad=GradPolicy()))
    res = execute(make_plan(ig, cfg), key=KEY)
    assert isinstance(res, GradResult) and res.mode == "pathwise"
    widths = np.array([2.0, 1.0])
    np.testing.assert_allclose(res.mean, 5.0, rtol=1e-5)
    np.testing.assert_allclose(res.grad_upper, res.mean / widths, rtol=1e-4)
    np.testing.assert_allclose(res.grad_lower, -res.mean / widths, rtol=1e-4)
    assert res.n_it_used == 3


def test_execute_grad_family_greeks():
    """The family route: per-scenario dual delta d(price)/d(strike) and
    vega d(price)/d(sigma) against central FDs of the closed-form price
    curve, within 3 grad-sigma each."""
    from repro.core.targets import asian_geometric_closed_form as price
    strikes, sigmas = np.array([90.0, 100.0, 110.0]), np.full(3, 0.2)
    fam = make_asian_greeks_family(strikes, sigmas, n_steps=4)
    cfg = VegasConfig(neval=8_000, max_it=8, ninc=64, chunk=2048,
                      execution=ExecutionConfig(grad=GradPolicy()))
    res = execute(make_plan(fam, cfg), key=KEY)
    assert isinstance(res, BatchGradResult)
    assert set(res.grad) == {"strike", "sigma"} and res.grad_sdev is not None

    kw = dict(s0=100.0, r=0.1, t_mat=1.0, n=4)
    for b, (k, sig) in enumerate(zip(strikes, sigmas)):
        dk = (price(strike=k + 0.5, sigma=sig, **kw)
              - price(strike=k - 0.5, sigma=sig, **kw))
        dv = (price(strike=k, sigma=sig + 5e-3, **kw)
              - price(strike=k, sigma=sig - 5e-3, **kw)) / 1e-2
        assert abs(res.grad["strike"][b] - dk) <= \
            3.0 * res.grad_sdev["strike"][b] + 1e-3, (b, res.grad, dk)
        assert abs(res.grad["sigma"][b] - dv) <= \
            3.0 * res.grad_sdev["sigma"][b] + 5e-2, (b, res.grad, dv)


def test_executor_rejects_hooks_on_grad_plans():
    fn_ig = make_gaussian_family(np.array([0.5]), dim=2).instance(0)
    cfg = VegasConfig(neval=1_000, max_it=2, ninc=16,
                      execution=ExecutionConfig(grad=GradPolicy()))
    plan = make_plan(fn_ig, cfg)
    with pytest.raises(ValueError, match="grad plan takes no"):
        execute(plan, key=KEY, checkpoint_cb=lambda it, st: None)
    with pytest.raises(ValueError, match="grad plan takes no"):
        execute(plan, key=KEY, fill_fn=lambda *a, **k: None)


def test_execute_grad_matches_primal_run_value():
    """The grad route's primal must be the plain run's eval-phase value —
    same backend, same frozen map, same eval stream (regression against the
    two phases drifting apart)."""
    fam = make_gaussian_family(np.array([0.5]), dim=2)
    ig = fam.instance(0)
    cfg = VegasConfig(neval=2_000, max_it=3, ninc=32, chunk=1024)
    gres = execute(make_plan(ig, cfg.with_execution(
        ExecutionConfig(grad=GradPolicy(with_sdev=False)))), key=KEY)
    # Reconstruct the same two-phase value by hand from the primal pieces.
    rcfg = cfg.resolve(ig.dim)
    st = core.init_state(ig, rcfg, KEY)
    st = jax.jit(lambda s: core.adapt_loop(s, ig, rcfg, 0))(st)
    m, _ = core.eval_phase(st.edges, st.n_h, ig, rcfg,
                           core.eval_key(KEY, rcfg))
    assert np.isclose(gres.mean, float(m), rtol=1e-6), (gres.mean, m)


# --- combine_results NaN-safety regression (§11 docstring contract) ----------

def test_combine_results_grad_nan_safe():
    """Reverse-mode through combine_results with (0, inf) sentinel rows —
    the early-stopped buffer shape — must yield finite gradients; the old
    bare ``1/wsum`` NaN-poisoned them via 0 * inf in the unselected
    branch."""
    def mean_of(m, n_done):
        results = jnp.stack(
            [jnp.stack([m, jnp.float32(0.0)]),
             jnp.stack([jnp.float32(0.02), jnp.float32(jnp.inf)])], axis=1)
        return core.combine_results(results, 0, n_done)[0]

    g = jax.grad(mean_of)(jnp.float32(0.3), 1)
    assert np.isfinite(float(g)) and np.isclose(float(g), 1.0), g

    # n_done = 0: nothing usable — the sentinel result, with a defined
    # (zero) gradient rather than NaN.
    v, g0 = jax.value_and_grad(mean_of)(jnp.float32(0.3), 0)
    assert float(v) == 0.0 and float(g0) == 0.0, (v, g0)

    # And the full sentinel tuple keeps its documented shape.
    results = jnp.stack([jnp.zeros(4), jnp.full(4, jnp.inf)], 1)
    mean, sdev, chi2, n_used = core.combine_results(results, 0, 4)
    assert (float(mean), float(chi2), int(n_used)) == (0.0, 0.0, 0)
    assert np.isinf(float(sdev))
