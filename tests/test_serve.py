"""Sweep-service regressions (ISSUE 7, DESIGN.md §12).

The serving layer must add ZERO numerics of its own: results returned
through the service are bitwise-equal to a direct `engine.execute` of the
same coalesced scenarios, admission rejects the full PlanError matrix
before anything touches a device, time budgets become hard iteration caps
(calibration batch uncapped, subsequent batches enforced), the
micro-batcher coalesces by compatibility class up to ``max_batch``, and
warm starts flow through the shared map pool.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.batch.engine import scenario_keys
from repro.batch.family import make_gaussian_family
from repro.core import VegasConfig
from repro.engine import ExecutionConfig, PlanError, StopPolicy, make_plan
from repro.engine import execute
from repro.serve import IntegrationRequest, SweepService

SKW = dict(neval=6_000, max_it=6, skip=2, ninc=32, chunk=2048)


def _req(**kw):
    base = dict(family="gaussian", params=[0.3], **SKW)
    base.update(kw)
    return IntegrationRequest(**base)


# --- parity: the service adds no numerics ------------------------------------

def test_served_results_bitwise_equal_direct_execute():
    """Two requests coalesced into one micro-batch return EXACTLY what a
    direct `execute` of the same scenarios (same per-request streams, same
    cold-start maps) computes — the service only routes and bills."""
    svc = SweepService(max_batch=16)
    t1 = svc.submit(_req(params=[0.3], seed=1, rtol=2e-3))
    t2 = svc.submit(_req(params=[0.5, 0.7], seed=2, rtol=2e-3))
    assert svc.drain() == 1  # one coalesced batch
    r1, r2 = t1.result(0), t2.result(0)

    fam = make_gaussian_family(np.array([0.3, 0.5, 0.7]))
    cfg = VegasConfig(execution=ExecutionConfig(
        batch="vmap", stop=StopPolicy(rtol=2e-3)), **SKW)
    keys = jnp.concatenate([scenario_keys(jax.random.PRNGKey(1), 1),
                            scenario_keys(jax.random.PRNGKey(2), 2)])
    direct = execute(make_plan(fam, cfg), keys=keys)

    np.testing.assert_array_equal(np.concatenate([r1.mean, r2.mean]),
                                  direct.mean)
    np.testing.assert_array_equal(np.concatenate([r1.sdev, r2.sdev]),
                                  direct.sdev)
    np.testing.assert_array_equal(np.concatenate([r1.n_it_used,
                                                  r2.n_it_used]),
                                  direct.n_it_used)


def test_served_request_bitwise_equal_run_batch():
    """A request's scenarios through the service ARE a `run_batch` of the
    same family under the request's key (same `scenario_keys` stream,
    same cold-start maps)."""
    from repro.batch import run_batch

    svc = SweepService()
    t = svc.submit(_req(params=[0.3, 0.5, 0.7], seed=11, rtol=2e-3))
    svc.drain()
    r = t.result(0)

    fam = make_gaussian_family(np.array([0.3, 0.5, 0.7]))
    cfg = VegasConfig(execution=ExecutionConfig(
        batch="vmap", stop=StopPolicy(rtol=2e-3)), **SKW)
    direct = run_batch(fam, cfg, key=jax.random.PRNGKey(11))
    np.testing.assert_array_equal(r.mean, direct.mean)
    np.testing.assert_array_equal(r.sdev, direct.sdev)
    np.testing.assert_array_equal(r.n_it_used, direct.n_it_used)


def test_results_invariant_to_coalescing():
    """A request's numbers do not depend on which batch it rode in: the
    same request served alone agrees with the coalesced serving (its RNG
    stream is pinned by its own seed, not its lane)."""
    svc = SweepService(max_batch=16)
    t1 = svc.submit(_req(params=[0.3], seed=1, rtol=2e-3))
    svc.submit(_req(params=[0.5, 0.7], seed=2, rtol=2e-3))
    svc.drain()
    coalesced = t1.result(0)

    alone = SweepService(max_batch=16)
    t_alone = alone.submit(_req(params=[0.3], seed=1, rtol=2e-3))
    alone.drain()
    solo = t_alone.result(0)
    np.testing.assert_allclose(coalesced.mean, solo.mean, rtol=1e-6)
    np.testing.assert_array_equal(coalesced.n_it_used, solo.n_it_used)


# --- admission ---------------------------------------------------------------

def test_admission_rejects_the_plan_error_matrix():
    """Every invalid combination dies at submit() with the engine's
    PlanError — nothing is enqueued, nothing touches a device."""
    svc = SweepService()
    bad = [
        _req(family="nope"),                          # unknown family
        _req(params=[]),                              # zero scenarios
        _req(time_budget_s=0.0),                      # non-positive budget
        _req(time_budget_s=-1.0),
        _req(rtol=-1e-3),                             # negative tolerance
        _req(rtol=1e-3, min_it=SKW["max_it"]),        # unreachable stop
        _req(backend="pallas-fused", dtype="float64"),  # dtype off-backend
        _req(backend="ref", tile=8),                  # knob misuse
        _req(backend="nope"),                         # unknown backend
        _req(family_kwargs=(("bogus", 3),)),          # builder rejection
    ]
    for req in bad:
        with pytest.raises(PlanError):
            svc.submit(req)
    stats = svc.stats()
    assert stats["requests"]["rejected"] == len(bad)
    assert stats["requests"]["submitted"] == 0
    assert svc.drain() == 0


# --- time budgets ------------------------------------------------------------

def test_time_budget_calibration_then_enforcement():
    svc = SweepService(max_batch=8)
    # Calibration batch: the class has no measured cost yet, so the budget
    # cannot be converted — the run is uncapped and flagged as such.
    t0 = svc.submit(_req(seed=0, time_budget_s=1e-9))
    svc.drain()
    r0 = t0.result(0)
    assert not r0.budget_enforced
    assert (r0.it_cap == SKW["max_it"]).all()
    assert not r0.capped

    # The class is now calibrated: an impossibly small budget caps at the
    # floor of 1 iteration, and the cap is a HARD ceiling (wins over the
    # fixed-length max_it).
    t1 = svc.submit(_req(seed=3, time_budget_s=1e-9))
    svc.drain()
    r1 = t1.result(0)
    assert r1.budget_enforced
    assert (r1.it_cap == 1).all()
    assert (r1.n_it_used == 1).all()
    assert r1.capped
    assert r1.billed_iterations == 1

    # A generous budget leaves the run at max_it, uncapped.
    t2 = svc.submit(_req(seed=4, time_budget_s=3600.0))
    svc.drain()
    r2 = t2.result(0)
    assert r2.budget_enforced
    assert (r2.it_cap == SKW["max_it"]).all()
    assert not r2.capped

    assert svc.stats()["iterations"]["capped_scenarios"] == 1


def test_no_budget_requests_never_capped():
    svc = SweepService()
    t = svc.submit(_req(seed=5))
    svc.drain()
    r = t.result(0)
    assert (r.n_it_used == SKW["max_it"]).all()
    assert not r.capped and not r.budget_enforced


# --- micro-batching ----------------------------------------------------------

def test_coalescing_groups_by_compat_key():
    svc = SweepService(max_batch=8)
    gauss = [svc.submit(_req(params=[p], seed=i))
             for i, p in enumerate([0.2, 0.4, 0.6])]
    ridge = svc.submit(_req(family="ridge",
                            params=[[1.0, 0.0, 0.0, 0.0]], seed=9))
    assert svc.drain() == 2  # one gaussian batch + one ridge batch
    ids = {t.result(0).batch_id for t in gauss}
    assert len(ids) == 1  # all three rode the same batch
    assert ridge.result(0).batch_id not in ids
    stats = svc.stats()
    assert stats["batches"]["count"] == 2
    assert stats["batches"]["max_occupancy"] == 3
    assert stats["requests"]["completed"] == 4
    assert stats["requests"]["scenarios_completed"] == 4


def test_max_batch_splits_without_splitting_requests():
    svc = SweepService(max_batch=4)
    tickets = [svc.submit(_req(params=[0.2 + 0.1 * i, 0.25 + 0.1 * i],
                               seed=i)) for i in range(3)]
    assert svc.drain() == 2  # 2+2 scenarios, then the remaining 2
    sizes = sorted(t.result(0).batch_size for t in tickets)
    assert sizes == [2, 4, 4]


def test_oversized_request_forms_its_own_batch():
    svc = SweepService(max_batch=2)
    t = svc.submit(_req(params=[0.2, 0.4, 0.6], seed=1))
    assert svc.drain() == 1
    assert t.result(0).batch_size == 3  # never split, even past max_batch


# --- warm starts -------------------------------------------------------------

def test_second_burst_warm_starts_from_the_pool(tmp_path):
    path = str(tmp_path / "serve_maps.npz")
    svc = SweepService(cache=path)
    t1 = svc.submit(_req(seed=1))
    svc.drain()
    assert not t1.result(0).warm_started
    t2 = svc.submit(_req(params=[0.3, 0.5], seed=2))  # different occupancy
    svc.drain()
    assert t2.result(0).warm_started  # pool maps broadcast to any B
    stats = svc.stats()
    assert stats["cache"] == {"hits": 1, "misses": 1, "hit_rate": 0.5}

    # The pool is shared state: a NEW service on the same path warm-starts
    # its very first batch.
    svc2 = SweepService(cache=path)
    t3 = svc2.submit(_req(seed=7))
    svc2.drain()
    assert t3.result(0).warm_started


# --- the long-lived worker ---------------------------------------------------

def test_background_worker_serves_submissions():
    with SweepService(max_wait_s=0.01) as svc:
        t1 = svc.submit(_req(seed=1, rtol=5e-3))
        t2 = svc.submit(_req(params=[0.6], seed=2, rtol=5e-3))
        r1 = t1.result(timeout=120.0)
        r2 = t2.result(timeout=120.0)
    assert r1.n_scenarios == 1 and r2.n_scenarios == 1
    stats = svc.stats()
    assert stats["requests"]["completed"] == 2
    assert stats["requests"]["in_flight"] == 0
    assert stats["throughput"]["requests_per_s"] > 0


def test_stats_reports_billing_and_cost_model():
    svc = SweepService()
    t = svc.submit(_req(seed=1, rtol=0.5))  # loose target: stops early
    svc.drain()
    r = t.result(0)
    stats = svc.stats()
    assert stats["iterations"]["billed"] == r.billed_iterations
    assert (stats["iterations"]["billed"]
            + stats["iterations"]["saved_vs_max_it"]
            == SKW["max_it"] * r.n_scenarios)
    assert stats["cost_model"]["classes_calibrated"] == 1
    assert stats["programs_cached"] == 1
    assert r.met_precision is not None and r.met_precision.all()
    assert r.billed_evals == r.billed_iterations * SKW["neval"]
