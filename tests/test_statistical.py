"""Statistical conformance suite (ISSUE 5): is the estimator CORRECT, not
just deterministic?

The rest of the suite proves determinism, backend parity, and engine
composition; nothing so far checked that the reported uncertainties mean
what they claim.  This module does, with three classic diagnostics from the
vegas literature:

  * **pull coverage** — over N independent seeded runs, the pulls
    ``(estimate - truth) / sdev`` must be ~ N(0, 1): coverage of the
    +-1.96 sigma interval inside binomial bounds, mean and width of the
    pull distribution near (0, 1).  The N runs execute as ONE vmapped
    program: an `IntegrandFamily` with N identical parameter rows gives N
    independent per-scenario RNG streams (``fold_in(key, b)``) over the
    same integrand — the conformance suite rides the batch engine.
  * **chi^2/dof sanity** — the per-run consistency diagnostic
    (`combine_results`) must sit in a sane band on well-behaved integrands;
    a tiny or huge value means the per-iteration sigmas are mis-scaled.
  * **1/sqrt(neval) scaling** — with adaptation frozen (alpha = beta = 0
    the loop is plain stratified MC), quadrupling ``neval`` must halve the
    combined sdev; with adaptation on, sdev must still shrink monotonically
    up the ladder.

Seeds come from ``REPRO_STATS_SEED`` (default 0) so CI can run a fixed seed
matrix (the `stats-smoke` job); every bound below is loose enough to hold
for any seed with overwhelming probability, yet tight enough that a
mis-scaled sdev or a biased estimator fails it immediately.
"""

import math
import os

import jax
import numpy as np
import pytest

from repro.batch import run_batch
from repro.batch.family import make_gaussian_family, make_ridge_family
from repro.core import VegasConfig, run
from repro.core import integrands as igs

SEED = int(os.environ.get("REPRO_STATS_SEED", "0"))
KEY = jax.random.PRNGKey(SEED)

#: Number of independent seeded runs per pull test (>= 50 per ISSUE 5).
N_RUNS = 50

#: Binomial bound for coverage of +-1.96 sigma at p=0.95, n=50: a true
#: N(0,1) pull distribution lands below 42/50 with probability ~2e-4.
MIN_COVERED = 42


def _pulls(family, cfg, key=KEY):
    res = run_batch(family, cfg, key=key)
    assert family.targets is not None
    return (res.mean - family.targets) / res.sdev, res


def _check_pulls(pulls, label):
    covered = int(np.sum(np.abs(pulls) <= 1.96))
    assert covered >= MIN_COVERED, (
        f"{label}: only {covered}/{len(pulls)} pulls within 1.96 sigma "
        f"(binomial floor {MIN_COVERED}) — sdev underestimates the error")
    # Mean ~ N(0, 1/sqrt(n)): 4.2 sigma bound.  A systematic bias (e.g. a
    # Jacobian error) shows up here long before it breaks coverage.
    assert abs(np.mean(pulls)) <= 4.2 / math.sqrt(len(pulls)), (
        f"{label}: pull mean {np.mean(pulls):+.3f} — biased estimator")
    # Width ~ 1 (loose: the sdev itself is an estimate and adaptation
    # correlates early iterations; 0.55/1.55 still catches factor-sqrt(2)
    # mis-scaling of the variance).
    assert 0.55 <= np.std(pulls) <= 1.55, (
        f"{label}: pull std {np.std(pulls):.3f} — mis-scaled sdev")


# --- pull-distribution coverage, one family per paper workload class ---------

def test_pull_coverage_gaussian_peak():
    # Same configuration as the CI PULLS.json artifact, by construction:
    # the artifact visualizes exactly the distribution asserted here.
    from benchmarks.bench_runs import PULL_CFG_KW, PULL_FAMILY_KW
    fam = make_gaussian_family(np.full(N_RUNS, 0.5), **PULL_FAMILY_KW)
    cfg = VegasConfig(**PULL_CFG_KW)
    pulls, res = _pulls(fam, cfg)
    _check_pulls(np.asarray(pulls), "gaussian_peak")
    assert 0.3 <= float(np.mean(res.chi2_dof)) <= 3.0, res.chi2_dof


def test_pull_coverage_ridge():
    direction = np.tile([0.6, 0.8, 1.0], (N_RUNS, 1))
    fam = make_ridge_family(direction, dim=3, n_peaks=8)
    cfg = VegasConfig(neval=6_000, max_it=10, skip=5, ninc=64, chunk=2048)
    pulls, res = _pulls(fam, cfg)
    _check_pulls(np.asarray(pulls), "ridge")
    assert 0.3 <= float(np.mean(res.chi2_dof)) <= 3.0, res.chi2_dof


def test_pull_coverage_diagonal():
    """The paper's main-diagonal ridge: peaks along (1, ..., 1) — the
    workload stratification exists for (classic VEGAS' worst case)."""
    direction = np.ones((N_RUNS, 3))
    fam = make_ridge_family(direction, dim=3, n_peaks=8)
    cfg = VegasConfig(neval=6_000, max_it=10, skip=5, ninc=64, chunk=2048)
    pulls, res = _pulls(fam, cfg)
    _check_pulls(np.asarray(pulls), "diagonal")
    assert 0.3 <= float(np.mean(res.chi2_dof)) <= 3.0, res.chi2_dof


# --- chi^2/dof sanity on single runs -----------------------------------------

@pytest.mark.parametrize("make_ig", [
    lambda: igs.make_cosine(dim=4),
    lambda: igs.make_gaussian(dim=3, sigma=0.2),
    lambda: igs.make_roos_arnold(dim=4),
], ids=["cosine", "gaussian", "roos_arnold"])
def test_chi2_dof_in_sane_band(make_ig):
    """With 15 dof entering the combination, chi^2/dof of a consistent run
    lies in [0.2, 5] (P(chi2_15/15 < 0.2) ~ 3e-4, P(> 5) ~ 1e-10); values
    outside mean the per-iteration sigma2 is wrong, not bad luck."""
    ig = make_ig()
    cfg = VegasConfig(neval=10_000, max_it=18, skip=2, ninc=64, chunk=4096)
    r = run(ig, cfg, key=KEY)
    assert r.n_it == 16
    assert 0.2 <= r.chi2_dof <= 5.0, r


# --- sdev ~ 1/sqrt(neval) ----------------------------------------------------

def test_sdev_scaling_frozen_map_is_sqrt_neval():
    """alpha = beta = 0 AND a pinned ``nstrat`` freeze map and
    stratification geometry: the loop is plain stratified MC on a fixed
    grid, so 4x neval must give exactly 2x smaller combined sdev (measured
    ratios sit within ~0.5% of 2; without pinning nstrat the cube count
    grows with neval and the rate is the BETTER N^(-1/2 - 1/d) stratified
    one — ~4x per 4x here, which is what this test would catch as a
    mis-scaling if it ever leaked into the frozen configuration)."""
    ig = igs.make_gaussian(dim=2, sigma=0.3)
    sdevs = []
    for neval in (4_000, 16_000, 64_000):
        cfg = VegasConfig(neval=neval, max_it=4, skip=0, ninc=32,
                          chunk=4096, alpha=0.0, beta=0.0, nstrat=4)
        sdevs.append(run(ig, cfg, key=KEY).sdev)
    for lo, hi in zip(sdevs[1:], sdevs[:-1]):
        ratio = hi / lo
        assert 1.85 <= ratio <= 2.15, (sdevs, ratio)


def test_sdev_scaling_adaptive_is_monotone():
    """With adaptation on the scaling is SUPER-1/sqrt(neval) (more evals
    also buy a better map), so assert monotone shrinkage plus at least the
    MC floor over the full 16x ladder."""
    ig = igs.make_gaussian(dim=3, sigma=0.2)
    sdevs = []
    for neval in (4_000, 16_000, 64_000):
        cfg = VegasConfig(neval=neval, max_it=8, skip=3, ninc=64,
                          chunk=4096)
        sdevs.append(run(ig, cfg, key=KEY).sdev)
    assert sdevs[0] > sdevs[1] > sdevs[2], sdevs
    assert sdevs[0] / sdevs[2] >= 2.5, sdevs
