"""End-to-end run benchmark: the whole VEGAS+ program through the unified
engine (plan -> execute), not just the fill phase.

Where BENCH_fill.json tracks the kernel trajectory (DESIGN.md §7), these
rows track what a user actually pays: full `core.run` wall clock — fill,
adaptation, aggregation, loop dispatch — per backend, plus the vmapped
batch program.  ``benchmarks.run --json`` extracts every ``run/*`` row into
``BENCH_run.json`` next to the fill artifact.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.batch import run_batch
from repro.batch.family import make_gaussian_family
from repro.core import VegasConfig
from repro.core import run as core_run
from repro.core.integrands import make_cosine, make_roos_arnold
from repro.engine import ExecutionConfig
from .common import emit, timeit


def run(fast=True):
    neval = 100_000 if fast else 1_000_000
    max_it = 6 if fast else 15
    base = dict(neval=neval, max_it=max_it, skip=2, ninc=256,
                chunk=min(neval, 1 << 14))
    key = jax.random.PRNGKey(0)

    for name, ig in [("roos_arnold", make_roos_arnold()),
                     ("cosine_d6", make_cosine(dim=6))]:
        for backend in ("ref", "pallas-fused"):
            cfg = VegasConfig(execution=ExecutionConfig(backend=backend),
                              **base)
            t = timeit(lambda: core_run(ig, cfg, key=key), repeats=3,
                       warmup=1)
            emit(f"run/{name}/{backend}", t,
                 f"evals_per_s={neval * max_it / t:,.0f}",
                 n_eval=neval, backend=backend, max_it=max_it)

    # The batched whole-run program (B scenarios, one jitted fori_loop).
    b = 4
    fam = make_gaussian_family(np.linspace(0.2, 0.8, b))
    cfg = VegasConfig(**base)
    t = timeit(lambda: run_batch(fam, cfg, key=key), repeats=3, warmup=1)
    emit(f"run/gaussian_family/B={b}/ref", t,
         f"evals_per_s={b * neval * max_it / t:,.0f}",
         n_eval=neval, backend="ref", max_it=max_it, batch=b)


if __name__ == "__main__":
    run()
