"""End-to-end run benchmark: the whole VEGAS+ program through the unified
engine (plan -> execute), not just the fill phase.

Where BENCH_fill.json tracks the kernel trajectory (DESIGN.md §7), these
rows track what a user actually pays: full `core.run` wall clock — fill,
adaptation, aggregation, loop dispatch — per backend, plus the vmapped
batch program, plus the adaptive early-stopping program (a `StopPolicy`
while_loop run, with the iterations it saved recorded in the row, §10).
``benchmarks.run --json`` extracts every ``run/*`` row into
``BENCH_run.json`` next to the fill artifact.

Standalone pull-histogram mode (the CI `stats-smoke` artifact)::

  PYTHONPATH=src python -m benchmarks.bench_runs --pulls --out PULLS.json

runs B seeded scenarios of the gaussian family in one vmapped program and
writes the pull distribution (estimate - truth) / sdev plus its histogram —
the raw material of the statistical conformance suite
(tests/test_statistical.py) as an inspectable artifact.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.batch import run_batch
from repro.batch.family import make_gaussian_family
from repro.core import VegasConfig
from repro.core import run as core_run
from repro.core.integrands import make_cosine, make_roos_arnold
from repro.engine import ExecutionConfig, StopPolicy, make_plan
from .common import emit, timeit


def _knobs(plan) -> dict:
    """The execution-knob fields every run/* row carries (BENCH_run.json
    rows must name the chunk/tile/mode they timed — the autotuner's paired
    rows are meaningless without them)."""
    interpret = plan.execution.interpret
    if "pallas" in plan.backend.name:
        from repro.kernels import resolve_interpret
        interpret = resolve_interpret(interpret)
    prec = getattr(plan, "precision", None)
    return dict(backend=plan.backend.name, chunk=int(plan.cfg.chunk),
                tile=plan.execution.tile, interpret=interpret,
                # §15: gates must never pair runs across precision policies,
                # so every row names the accumulation dtype it timed.
                accum_dtype=(prec.accum_dtype if prec is not None
                             else "float32"))


def run(fast=True):
    neval = 100_000 if fast else 1_000_000
    max_it = 6 if fast else 15
    base = dict(neval=neval, max_it=max_it, skip=2, ninc=256,
                chunk=min(neval, 1 << 14))
    key = jax.random.PRNGKey(0)

    for name, ig in [("roos_arnold", make_roos_arnold()),
                     ("cosine_d6", make_cosine(dim=6))]:
        for backend in ("ref", "pallas-fused"):
            cfg = VegasConfig(execution=ExecutionConfig(backend=backend),
                              **base)
            t = timeit(lambda: core_run(ig, cfg, key=key), repeats=3,
                       warmup=1)
            emit(f"run/{name}/{backend}", t,
                 f"evals_per_s={neval * max_it / t:,.0f}",
                 n_eval=neval, max_it=max_it, **_knobs(make_plan(ig, cfg)))

    # Adaptive early stopping: the same program under a loose rtol target.
    # The row records the iterations the while_loop did not run — the GPU
    # cycles a convergence-targeted run saves over the fixed loop (§10).
    ig = make_cosine(dim=6)
    cfg_stop = VegasConfig(
        execution=ExecutionConfig(stop=StopPolicy(rtol=5e-4, min_it=2)),
        **base)
    res = core_run(ig, cfg_stop, key=key)
    t = timeit(lambda: core_run(ig, cfg_stop, key=key), repeats=3, warmup=1)
    emit("run/cosine_d6/ref/rtol=5e-4", t,
         f"n_it_used={res.n_it_used}/{max_it} "
         f"it_saved={max_it - res.n_it_used}",
         n_eval=neval, max_it=max_it,
         n_it_used=int(res.n_it_used),
         it_saved=int(max_it - res.n_it_used),
         **_knobs(make_plan(ig, cfg_stop)))

    # The batched whole-run program (B scenarios, one jitted fori_loop).
    b = 4
    fam = make_gaussian_family(np.linspace(0.2, 0.8, b))
    cfg = VegasConfig(**base)
    t = timeit(lambda: run_batch(fam, cfg, key=key), repeats=3, warmup=1)
    emit(f"run/gaussian_family/B={b}/ref", t,
         f"evals_per_s={b * neval * max_it / t:,.0f}",
         n_eval=neval, max_it=max_it, batch=b, **_knobs(make_plan(fam, cfg)))

    # ... and with per-scenario stop masks: scenario-iterations saved.
    cfg_bstop = VegasConfig(
        execution=ExecutionConfig(stop=StopPolicy(rtol=5e-4, min_it=2)),
        **base)
    bres = run_batch(fam, cfg_bstop, key=key)
    t = timeit(lambda: run_batch(fam, cfg_bstop, key=key), repeats=3,
               warmup=1)
    saved = b * max_it - int(bres.n_it_used.sum())
    emit(f"run/gaussian_family/B={b}/ref/rtol=5e-4", t,
         f"n_it_used={bres.n_it_used.tolist()} it_saved={saved}",
         n_eval=neval, max_it=max_it, batch=b, it_saved=saved,
         **_knobs(make_plan(fam, cfg_bstop)))

    autotune_pairs(fast=fast)


def _steady_single(plan, key, repeats=2):
    """Steady-state wall clock of a single-scenario plan: one prebuilt
    non-donating program, compile excluded (the regime where knob choices
    are measurable at all — a fresh jit per call re-pays trace+compile,
    which drowns the chunk/tile effects the autotuner optimizes)."""
    from repro.core import integrator as core_mod
    from repro.engine.executor import make_single_program
    prog = make_single_program(plan)
    state = core_mod.init_state(plan.workload, plan.cfg, key)
    return timeit(lambda: prog(state), repeats=repeats, warmup=1)


def _steady_family(plan, key, repeats=2):
    """Steady-state wall clock of a batched family plan (same contract)."""
    from repro.batch.engine import scenario_keys
    from repro.engine.executor import (make_family_program,
                                       uniform_family_edges)
    prog = make_family_program(plan)
    fam = plan.workload
    args = (fam.params, scenario_keys(key, plan.batch_size),
            uniform_family_edges(fam, plan.cfg, plan.batch_size))
    return timeit(lambda: prog(*args), repeats=repeats, warmup=1)


def autotune_pairs(fast=True):
    """The autotuner's paired rows (ISSUE 8 acceptance): on each benchmark
    shape, the same workload with default knobs vs `autotune=True` knobs,
    timed steady-state.  ``benchmarks.run --gate-run`` pairs the
    ``.../default`` and ``.../autotuned`` rows and fails when autotuning
    made a shape slower.  Both shapes are high-dim/low-n_cubes, where the
    default chunk's n_cap padding (cfg.resolve rounds n_cap UP to a chunk
    multiple) is the dominant recoverable cost on CPU."""
    key = jax.random.PRNGKey(0)
    neval = 100_000 if fast else 500_000
    max_it = 6
    shapes = [
        ("roos_arnold_d10", make_roos_arnold(),
         dict(neval=neval, max_it=max_it, skip=2, ninc=256, chunk=1 << 14)),
        ("gaussian_family_d10/B=4",
         make_gaussian_family(np.linspace(0.2, 0.8, 4), dim=10),
         dict(neval=neval // 2, max_it=max_it, skip=2, ninc=128,
              chunk=1 << 14)),
    ]
    for name, workload, kw in shapes:
        is_family = hasattr(workload, "params")
        b = workload.batch_size if is_family else 1
        steady = _steady_family if is_family else _steady_single
        default_plan = make_plan(workload, VegasConfig(**kw))
        tuned_plan = make_plan(workload, VegasConfig(
            execution=ExecutionConfig(autotune=True), **kw))
        rep = tuned_plan.tuned
        t_def = steady(default_plan, key)
        t_tun = steady(tuned_plan, key)
        evals = b * kw["neval"] * max_it
        emit(f"run/autotune/{name}/default", t_def,
             f"evals_per_s={evals / t_def:,.0f}",
             n_eval=kw["neval"], max_it=max_it, batch=b,
             predicted_s=(None if rep is None
                          else round(rep.predicted_default_s, 6)),
             **_knobs(default_plan))
        emit(f"run/autotune/{name}/autotuned", t_tun,
             f"evals_per_s={evals / t_tun:,.0f} "
             f"speedup={t_def / t_tun:.2f}x",
             n_eval=kw["neval"], max_it=max_it, batch=b,
             predicted_s=(None if rep is None
                          else round(rep.predicted_s, 6)),
             **_knobs(tuned_plan))


#: The gaussian-peak pull-distribution setup, shared VERBATIM with
#: tests/test_statistical.py (which imports these): the PULLS.json artifact
#: CI uploads must describe exactly the distribution the conformance suite
#: asserts on — one definition, so the two cannot drift.
PULL_FAMILY_KW = dict(dim=3, sigma=0.2)
PULL_CFG_KW = dict(neval=6_000, max_it=10, skip=5, ninc=64, chunk=2048)


def pulls(out: str = "PULLS.json", b: int = 50, seed: int = 0) -> dict:
    """B independent seeded runs of one integrand as ONE vmapped program
    (identical params, per-scenario keys), reduced to the pull distribution
    ``(estimate - truth) / sdev`` and a histogram.  Written as JSON for the
    CI artifact; tests/test_statistical.py asserts the same quantities on
    the same configuration (PULL_FAMILY_KW / PULL_CFG_KW)."""
    import json

    fam = make_gaussian_family(np.full(b, 0.5), **PULL_FAMILY_KW)
    cfg = VegasConfig(**PULL_CFG_KW)
    res = run_batch(fam, cfg, key=jax.random.PRNGKey(seed))
    p = (res.mean - fam.targets) / res.sdev
    edges = np.linspace(-4.0, 4.0, 17)
    hist, _ = np.histogram(p, bins=edges)
    payload = {
        "family": fam.name, "b": b, "seed": seed, **PULL_CFG_KW,
        "pulls": np.round(p, 6).tolist(),
        "hist_edges": edges.tolist(), "hist_counts": hist.tolist(),
        "mean_pull": float(np.mean(p)), "std_pull": float(np.std(p)),
        "frac_within_1p96": float(np.mean(np.abs(p) <= 1.96)),
        "mean_chi2_dof": float(np.mean(res.chi2_dof)),
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out}: mean_pull={payload['mean_pull']:+.3f} "
          f"std_pull={payload['std_pull']:.3f} "
          f"frac|pull|<=1.96={payload['frac_within_1p96']:.2f}")
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--pulls", action="store_true",
                    help="write the pull-distribution artifact instead of "
                         "timing rows")
    ap.add_argument("--out", default="PULLS.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=50)
    args = ap.parse_args()
    if args.pulls:
        pulls(out=args.out, b=args.batch, seed=args.seed)
    else:
        run()
