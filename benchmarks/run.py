"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig8,table1,...]
      [--json OUT.json] [--gate-fill]

Prints ``name,us_per_call,derived`` CSV rows.  Default (fast) mode scales
n_eval down so the suite completes on a single CPU core in minutes; --full
uses paper-scale parameters.

``--json OUT.json`` additionally writes every row as a structured record
(name, us_per_call, derived, n_eval, backend where known) plus run metadata
(git sha, jax version/backend, mode) — and extracts three trajectory
artifacts next to it: the fill rows into ``BENCH_fill.json`` (the kernel
trajectory DESIGN.md §7 tracks across PRs), the end-to-end ``run/*`` rows
into ``BENCH_run.json`` (whole-run wall clock per backend,
benchmarks/bench_runs.py), and the ``serve/*`` rows into
``BENCH_serve.json`` (service requests/sec at fixed precision,
benchmarks/bench_serve.py).

``--gate-fill`` turns the P-V2 vs P-V3 comparison into a regression gate:
exit nonzero if any ``fill_fused`` row is slower than its ``fill_pallas``
twin (CI's bench-smoke job runs ``--only table1,batch --json --gate-fill``).
``--gate-run`` does the same for the autotuner (ISSUE 8): the
``run/autotune/*`` rows pair each shape's default-knob timing with its
``autotune=True`` twin, and the gate fails if autotuning made any shape
slower — or never made one faster.  The ``calibrate`` suite (not in the
default set's hot path, but first when selected) measures the cost-model
grid and writes ``COST_TABLE.json`` for those autotuned rows to consume.

``--gate-abs`` is the ABSOLUTE trajectory gate (ISSUE 9): every current
fill/run row is paired with the best committed prior row of the same
(name, backend, device_kind, interpret) — read from ``BENCH_fill.json`` /
``BENCH_run.json`` on disk BEFORE ``--json`` overwrites them — and the gate
fails on a >1.10x wall-clock regression.  Rows with no prior are skipped
(a new shape/backend/device cannot regress against nothing), and so are
rows on the generic ``device_kind="cpu"`` (absolute seconds are not
comparable across unidentified hosts — see ``gate_abs``), so the gate
auto-arms as real-hardware artifacts accumulate and auto-skips on silicon
with no history — the compiled-GPU path's first run records, the second
gates.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def fill_rows(rows: list[dict]) -> list[dict]:
    """The fill perf-trajectory subset: every row timing a fill variant."""
    return [r for r in rows if "/fill" in r["name"]]


def run_rows(rows: list[dict]) -> list[dict]:
    """The end-to-end trajectory subset: whole-run timings (bench_runs.py)."""
    return [r for r in rows if r["name"].startswith("run/")]


def serve_rows(rows: list[dict]) -> list[dict]:
    """The serving-throughput subset: requests/sec rows (bench_serve.py)."""
    return [r for r in rows if r["name"].startswith("serve/")]


def _accum(r: dict) -> str:
    """A row's accumulation dtype for gate pairing (§15).  Rows stamped
    before accum_dtype existed carry none — they were all f32-accumulated,
    so they normalize to 'float32' and can only ever pair with f32 rows;
    a widened-f64 run is never compared against an f32 timing."""
    return r.get("accum_dtype") or "float32"


def gate_run(rows: list[dict]) -> list[str]:
    """The autotuner's regression gate (ISSUE 8): pair each
    ``run/autotune/<shape>/autotuned`` row with its ``/default`` twin and
    return a failure message per pair where autotuning made the shape
    slower than the default knobs (beyond a 5% timing-noise allowance) —
    plus one failure if NO measured pair came out strictly faster (an
    autotuner that never wins is not earning its keep)."""
    base = {r["name"].replace("/default", ""): r for r in rows
            if r["name"].startswith("run/autotune/")
            and r["name"].endswith("/default")}
    failures, pairs, wins = [], 0, 0
    for r in rows:
        if not (r["name"].startswith("run/autotune/")
                and r["name"].endswith("/autotuned")):
            continue
        twin = base.get(r["name"].replace("/autotuned", ""))
        if twin is None:
            continue
        if r.get("interpret") != twin.get("interpret"):
            # Same universe rule as gate_fill: interpreter vs compiled
            # timings are incomparable.
            continue
        if _accum(r) != _accum(twin):
            # So are f32- vs f64-accumulated runs (§15).
            continue
        pairs += 1
        if r["us_per_call"] > twin["us_per_call"] * 1.05:
            failures.append(
                f"GATE: {r['name']} ({r['us_per_call']:.0f}us, "
                f"chunk={r.get('chunk')} tile={r.get('tile')}) slower than "
                f"{twin['name']} ({twin['us_per_call']:.0f}us, "
                f"chunk={twin.get('chunk')} tile={twin.get('tile')})")
        if r["us_per_call"] < twin["us_per_call"]:
            wins += 1
    if pairs == 0:
        failures.append("GATE: no autotuned/default pair was measured — "
                        "--gate-run has nothing to check")
    elif wins == 0:
        failures.append(f"GATE: autotuning won on none of the {pairs} "
                        f"measured shapes")
    return failures


#: --gate-abs failure threshold: current / best-prior wall clock.
ABS_GATE_RATIO = 1.10


def load_prior_rows(paths: list[str]) -> list[dict]:
    """Prior BENCH artifact rows for ``--gate-abs`` — tolerant of missing
    or malformed files (no history is a skip, not an error)."""
    rows: list[dict] = []
    for path in paths:
        try:
            with open(path) as f:
                rows.extend(json.load(f).get("rows", []))
        except (OSError, ValueError):
            continue
    return rows


def gate_abs(rows: list[dict], prior_rows: list[dict],
             ratio: float = ABS_GATE_RATIO) -> tuple[list[str], int, int]:
    """The absolute wall-clock gate: pair each current row with the BEST
    prior row of the same (name, backend, device_kind, interpret) and fail
    when current > ``ratio`` x prior.  Prior rows recorded before
    device_kind stamping match any device (legacy wildcard); rows with no
    prior at all are skipped.  Rows whose device_kind is the generic
    ``"cpu"`` are also skipped: that string names no actual hardware, so
    "same device_kind" cannot hold across hosts (CI runners vs dev boxes),
    and measured same-host run-to-run variance on the small CPU rows
    (up to ~1.3x) swamps the threshold — absolute seconds only gate where
    they are comparable, i.e. real accelerator rows whose device_kind is
    a hardware model string (DESIGN.md §14.4).  Returns
    (failures, checked, skipped)."""
    best: dict[tuple, float] = {}
    legacy: dict[tuple, float] = {}
    for r in prior_rows:
        us = r.get("us_per_call")
        if not us:
            continue
        k = (r.get("name"), r.get("backend"), r.get("interpret"), _accum(r))
        if r.get("device_kind") is None:
            legacy[k] = min(legacy.get(k, us), us)
        else:
            kd = k + (r["device_kind"],)
            best[kd] = min(best.get(kd, us), us)
    failures, checked, skipped = [], 0, 0
    for r in rows:
        if (r.get("device_kind") or "cpu") == "cpu":
            skipped += 1
            continue
        k = (r.get("name"), r.get("backend"), r.get("interpret"), _accum(r))
        prior = best.get(k + (r.get("device_kind"),), legacy.get(k))
        if prior is None:
            skipped += 1
            continue
        checked += 1
        if r["us_per_call"] > prior * ratio:
            failures.append(
                f"GATE: {r['name']} ({r['us_per_call']:.0f}us, "
                f"backend={r.get('backend')} "
                f"device_kind={r.get('device_kind')}) regressed "
                f"{r['us_per_call'] / prior:.2f}x vs best prior "
                f"{prior:.0f}us (limit {ratio:.2f}x)")
    return failures, checked, skipped


def gate_fill(rows: list[dict]) -> list[str]:
    """Pair each fused fill row with its baseline-pallas twin; return a
    failure message per pair where fused is slower."""
    base = {r["name"].replace("/fill_pallas", ""): r for r in rows
            if r["name"].endswith("/fill_pallas")}
    failures = []
    for r in rows:
        if not r["name"].endswith("/fill_fused"):
            continue
        twin = base.get(r["name"].replace("/fill_fused", ""))
        if twin is None:
            continue
        if r.get("interpret") != twin.get("interpret"):
            # Interpreter vs compiled-Mosaic timings are different universes;
            # comparing across modes gates nothing real.
            continue
        if _accum(r) != _accum(twin):
            # Precision policies are different universes too (§15).
            continue
        if r["us_per_call"] > twin["us_per_call"]:
            failures.append(
                f"GATE: {r['name']} ({r['us_per_call']:.0f}us) slower than "
                f"{twin['name']} ({twin['us_per_call']:.0f}us)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    help="write structured results + BENCH_fill.json")
    ap.add_argument("--gate-fill", action="store_true",
                    help="exit nonzero if the fused fill is slower than the "
                         "baseline pallas fill on any measured shape")
    ap.add_argument("--gate-run", action="store_true",
                    help="exit nonzero if an autotuned run is slower than "
                         "its default-knob twin on any measured shape, or "
                         "if autotuning never won")
    ap.add_argument("--gate-abs", action="store_true",
                    help="exit nonzero if any fill/run row regressed more "
                         "than 1.10x vs the best prior BENCH row of the "
                         "same (name, backend, device_kind, interpret); "
                         "rows with no prior are skipped")
    args = ap.parse_args()
    fast = not args.full
    only = set(filter(None, args.only.split(",")))

    # --gate-abs priors must be read BEFORE --json overwrites the artifacts:
    # the committed repo copies (cwd) plus any previous copies in the --json
    # output directory.
    prior_rows: list[dict] = []
    if args.gate_abs:
        dirs = ["."]
        if args.json:
            dirs.append(os.path.dirname(os.path.abspath(args.json)))
        prior_rows = load_prior_rows(
            [os.path.join(d, f) for d in dict.fromkeys(dirs)
             for f in ("BENCH_fill.json", "BENCH_run.json")])

    from . import (bench_applications, bench_batch, bench_breakdown,
                   bench_calibrate, bench_grad, bench_integrands,
                   bench_multidevice, bench_runs, bench_scaling, bench_serve,
                   bench_stratification)
    from . import common

    suites = {
        "calibrate": bench_calibrate,
        "table1": bench_breakdown,
        "table7": bench_integrands,
        "fig3": bench_scaling,
        "fig8": bench_stratification,
        "table8": bench_multidevice,
        "table9_10": bench_applications,
        "batch": bench_batch,
        "run": bench_runs,
        "grad": bench_grad,
        "serve": bench_serve,
    }
    common.reset_rows()
    print("name,us_per_call,derived")
    for key, mod in suites.items():
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            mod.run(fast=fast)
        except Exception as e:  # keep the harness alive per-suite
            print(f"{key}/ERROR,0,{type(e).__name__}: {e}", file=sys.stdout)
        print(f"{key}/_suite_wall,{(time.time()-t0)*1e6:.0f},",
              file=sys.stdout)

    if args.json:
        import jax
        meta = {
            "git_sha": common.git_sha(),
            "jax_version": jax.__version__,
            "jax_backend": jax.default_backend(),
            "mode": "full" if args.full else "fast",
            "rows": common.ROWS,
        }
        with open(args.json, "w") as f:
            json.dump(meta, f, indent=1)
        out_dir = os.path.dirname(os.path.abspath(args.json))
        wrote = [args.json]
        for fname, subset in [("BENCH_fill.json", fill_rows(common.ROWS)),
                              ("BENCH_run.json", run_rows(common.ROWS)),
                              ("BENCH_serve.json", serve_rows(common.ROWS))]:
            if not subset:
                continue
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                json.dump({**{k: v for k, v in meta.items() if k != "rows"},
                           "rows": subset}, f, indent=1)
            wrote.append(path)
        print(f"# wrote {' and '.join(wrote)}", file=sys.stderr)

    if args.gate_fill:
        failures = gate_fill(common.ROWS)
        for msg in failures:
            print(msg, file=sys.stderr)
        if failures:
            sys.exit(2)
        n = sum(1 for r in common.ROWS
                if r["name"].endswith("/fill_fused")
                and r["name"].replace("/fill_fused", "/fill_pallas")
                in {x["name"] for x in common.ROWS})
        if n == 0:
            # A gate that measured nothing is a broken gate, not a green one
            # (e.g. --only dropped table1, or the fill rows were renamed).
            print("GATE: no fused/baseline fill pair was measured — "
                  "--gate-fill has nothing to check", file=sys.stderr)
            sys.exit(2)
        print(f"# fill gate OK ({n} fused shapes measured)", file=sys.stderr)

    if args.gate_run:
        failures = gate_run(common.ROWS)
        for msg in failures:
            print(msg, file=sys.stderr)
        if failures:
            sys.exit(2)
        n = sum(1 for r in common.ROWS
                if r["name"].startswith("run/autotune/")
                and r["name"].endswith("/autotuned"))
        print(f"# run gate OK ({n} autotuned shapes measured)",
              file=sys.stderr)

    if args.gate_abs:
        failures, checked, skipped = gate_abs(
            fill_rows(common.ROWS) + run_rows(common.ROWS), prior_rows)
        for msg in failures:
            print(msg, file=sys.stderr)
        if failures:
            sys.exit(2)
        print(f"# abs gate OK ({checked} rows checked vs prior, "
              f"{skipped} skipped: generic-cpu or no prior)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
