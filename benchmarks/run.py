"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig8,table1,...]

Prints ``name,us_per_call,derived`` CSV rows.  Default (fast) mode scales
n_eval down so the suite completes on a single CPU core in minutes; --full
uses paper-scale parameters.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    fast = not args.full
    only = set(filter(None, args.only.split(",")))

    from . import (bench_applications, bench_batch, bench_breakdown,
                   bench_integrands, bench_lm_step, bench_multidevice,
                   bench_scaling, bench_stratification)

    suites = {
        "table1": bench_breakdown,
        "table7": bench_integrands,
        "fig3": bench_scaling,
        "fig8": bench_stratification,
        "table8": bench_multidevice,
        "table9_10": bench_applications,
        "batch": bench_batch,
        "lm": bench_lm_step,
    }
    print("name,us_per_call,derived")
    for key, mod in suites.items():
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            mod.run(fast=fast)
        except Exception as e:  # keep the harness alive per-suite
            print(f"{key}/ERROR,0,{type(e).__name__}: {e}", file=sys.stdout)
        print(f"{key}/_suite_wall,{(time.time()-t0)*1e6:.0f},",
              file=sys.stdout)


if __name__ == "__main__":
    main()
