"""Paper Figs. 6-7 / Tables 9-10: the application integrands — Asian option
pricing and the Feynman path integral — accuracy vs wall time, plus the
closed-form validation the paper doesn't have (geometric Asian, lattice-exact
Gaussian path integral)."""

from __future__ import annotations

import time

import jax

from repro.core import run as vegas_run
from repro.core import VegasConfig
from repro.core.integrands import make_asian_option, make_feynman_path
from .common import emit


def run(fast=True):
    neval = 200_000 if fast else 2_000_000
    cfg = VegasConfig(neval=neval, max_it=15, skip=5, ninc=512,
                      chunk=min(neval, 1 << 14))

    for name, ig in [("asian_geometric", make_asian_option(geometric=True)),
                     ("asian_arithmetic", make_asian_option(geometric=False)),
                     ("feynman_path", make_feynman_path())]:
        t0 = time.perf_counter()
        r = vegas_run(ig, cfg, key=jax.random.PRNGKey(0))
        dt = time.perf_counter() - t0
        if ig.target is not None:
            pull = (r.mean - ig.target) / r.sdev
            derived = (f"mean={r.mean:.6g} sdev={r.sdev:.2e} "
                       f"target={ig.target:.6g} pull={pull:+.2f}")
        else:
            derived = f"mean={r.mean:.6g} sdev={r.sdev:.2e} chi2={r.chi2_dof:.2f}"
        emit(f"table9_10/{name}", dt, derived)


if __name__ == "__main__":
    run()
