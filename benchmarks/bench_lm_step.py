"""Substrate benchmark: reduced-config train-step + decode-step timing per
assigned architecture (CPU proxy numbers; TPU performance model lives in the
roofline table)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.train import optimizer as OPT
from repro.train.data import synthetic_batch
from repro.train.train_step import init_state, make_train_step
from .common import emit, timeit


def run(fast=True):
    archs = (["smollm_135m", "mamba2_1_3b", "phi3_5_moe_42b"]
             if fast else configs.ARCHS)
    for arch in archs:
        cfg = configs.get_reduced(arch)
        opt = OPT.for_config(cfg)
        step = jax.jit(make_train_step(cfg, opt))
        state = init_state(jax.random.PRNGKey(0), cfg, opt)
        batch = synthetic_batch(0, 0, batch=4, seq=64, vocab=cfg.vocab)
        if cfg.xattn_memory_len:
            batch["memory"] = jnp.zeros((4, cfg.xattn_memory_len, cfg.d_model),
                                        jnp.float32)
        t = timeit(lambda: step(state, batch)[1]["loss"], repeats=3)
        emit(f"lm_train_step/{arch}", t, f"tok_per_s={4*64/t:,.0f}")

        cache = T.init_cache(cfg, 2, 64, dtype=jnp.float32)
        dstep = jax.jit(lambda p, c, tok, pos: T.decode_step(p, c, tok, pos, cfg))
        tok = jnp.zeros((2,), jnp.int32)
        t = timeit(lambda: dstep(state["params"], cache, tok,
                                 jnp.array(1, jnp.int32))[0], repeats=3)
        emit(f"lm_decode_step/{arch}", t, f"tok_per_s={2/t:,.0f}")


if __name__ == "__main__":
    run()
