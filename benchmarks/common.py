"""Shared benchmark utilities: timing, CSV output, and machine-readable row
collection for ``benchmarks.run --json`` (the perf-trajectory artifact)."""

from __future__ import annotations

import subprocess
import time

import jax

# Every emit() appends here; benchmarks/run.py serializes the list (plus run
# metadata) to --json and extracts the fill rows into BENCH_fill.json.
ROWS: list[dict] = []


def reset_rows() -> None:
    ROWS.clear()


def git_sha() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short=12", "HEAD"],
                              capture_output=True, text=True,
                              timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def timeit(fn, *args, repeats=3, warmup=1):
    """Median wall time of fn(*args) in seconds (block_until_ready aware)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def device_kind() -> str:
    """The device kind every row is stamped with (the cost-table key too:
    engine.autotune keys calibrations the same way)."""
    try:
        return jax.devices()[0].device_kind
    except Exception:
        return "unknown"


def emit(name: str, seconds: float, derived: str = "", **fields):
    """One CSV row ``name,us_per_call,derived`` + a structured record.

    Extra keyword fields (``n_eval=...``, ``backend=...``) go into the JSON
    record only — the CSV format is unchanged.  Every record is stamped with
    ``device_kind`` so BENCH_*.json artifacts from different machines are
    distinguishable (and comparable against the matching COST_TABLE.json).
    """
    print(f"{name},{seconds * 1e6:.1f},{derived}")
    ROWS.append({"name": name, "us_per_call": round(seconds * 1e6, 1),
                 "derived": derived, "device_kind": device_kind(), **fields})
