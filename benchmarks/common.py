"""Shared benchmark utilities: timing + CSV output."""

from __future__ import annotations

import time

import jax


def timeit(fn, *args, repeats=3, warmup=1):
    """Median wall time of fn(*args) in seconds (block_until_ready aware)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, seconds: float, derived: str = ""):
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")
