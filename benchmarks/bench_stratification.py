"""Paper Fig. 8: the value of adaptive stratification.  cuVegas with
beta=0.25/0.75 vs beta=0 (classic VEGAS as in m-CUBES) on peaked integrands
(Ridge, Feynman path): at equal function evaluations, adaptive stratification
must deliver a lower standard error.  alpha=1.5, discount first 5 iterations
(the paper's protocol, n_intervals scaled to our suite)."""

from __future__ import annotations

import time

import jax

from repro.core import run as vegas_run
from repro.core import VegasConfig
from repro.core.integrands import make_feynman_path, make_ridge
from .common import emit


def run(fast=True):
    neval = 100_000 if fast else 1_000_000
    cases = [("ridge", lambda: make_ridge(n_peaks=100 if fast else 1000)),
             ("feynman", make_feynman_path)]
    for name, mk in cases:
        ig = mk()
        out = {}
        for beta in (0.0, 0.25, 0.75):
            cfg = VegasConfig(neval=neval, max_it=15, skip=5, ninc=500,
                              alpha=1.5, beta=beta, chunk=min(neval, 1 << 14))
            t0 = time.perf_counter()
            r = vegas_run(ig, cfg, key=jax.random.PRNGKey(2))
            dt = time.perf_counter() - t0
            out[beta] = (r, dt)
            pull = (r.mean - ig.target) / r.sdev if ig.target else 0.0
            emit(f"fig8/{name}/beta={beta}", dt,
                 f"sdev={r.sdev:.3e} pull={pull:+.2f} chi2={r.chi2_dof:.2f}")
        gain = out[0.0][0].sdev / max(out[0.75][0].sdev, 1e-30)
        emit(f"fig8/{name}/strat_gain", 0.0,
             f"sdev_ratio_beta0_over_beta075={gain:.2f}")


if __name__ == "__main__":
    run()
