"""Batched engine vs the serial loop: B concurrent scenarios in one jitted
program (repro.batch) against B sequential ``core.run`` calls.

The claim to reproduce (ISSUE 2 acceptance): batched wall clock beats the
serial loop — the accelerator sees one big vmapped fill instead of B small
ones, and the B-1 extra dispatch/compile round-trips disappear."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.batch import run_batch, run_serial
from repro.batch.family import make_gaussian_family
from repro.core import VegasConfig
from .common import emit


def _wall(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(fast=True):
    neval = 20_000 if fast else 200_000
    cfg = VegasConfig(neval=neval, max_it=8, skip=3, ninc=64,
                      chunk=min(neval, 1 << 12))
    key = jax.random.PRNGKey(0)
    for b in (2, 4, 8):
        fam = make_gaussian_family(np.linspace(0.2, 0.8, b))
        # warm both paths once so compile time is excluded from the ratio
        run_batch(fam, cfg, key=key)
        t_batch = _wall(lambda: run_batch(fam, cfg, key=key))
        run_serial(fam, cfg, key=key)
        t_serial = _wall(lambda: run_serial(fam, cfg, key=key))
        emit(f"batch/B={b}/batched", t_batch,
             f"speedup={t_serial / t_batch:.2f}x neval={neval}")
        emit(f"batch/B={b}/serial", t_serial, f"neval={neval}")


if __name__ == "__main__":
    run()
