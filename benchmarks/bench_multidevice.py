"""Paper Fig. 5 / Table 8: multi-device scaling of the fill phase.

Runs the sharded fill on 1/2/4/8 forced host devices in subprocesses.
HONESTY NOTE: this container has ONE physical core, so host "devices" are
time-sliced and wall-clock speedup is structurally ~1x here; the table
reports the two quantities that ARE meaningful in the dry-run setting:
  * per-device eval count (work drops 1/n — the paper's C1 balance), and
  * psum'd accumulator bytes (constant in n_eval — the Amdahl argument that
    gave cuVegas 0.85 efficiency at 8 GPUs, Table 8).
Real-TPU wall-clock scaling is a hardware measurement, not reproducible here.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit

_WORKER = r"""
import os, sys, json, time
n = int(sys.argv[1])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax
from repro.core import integrator as I
from repro.core.integrands import make_ridge
from repro.dist import sharded_fill as SF
from repro.launch.mesh import make_mesh

ig = make_ridge(dim=4, n_peaks=200)
cfg = I.VegasConfig(neval=200_000, max_it=4, ninc=512, chunk=8192).resolve(ig.dim)
mesh = make_mesh((n,), ("data",))
fill = SF.make_sharded_fill(mesh, ("data",), cfg)
st = I.init_state(ig, cfg, jax.random.PRNGKey(0))
key = jax.random.fold_in(st.key, 0)
r = jax.block_until_ready(fill(st.edges, st.n_h, key, ig))   # compile
t0 = time.perf_counter()
for _ in range(3):
    r = jax.block_until_ready(fill(st.edges, st.n_h, key, ig))
dt = (time.perf_counter() - t0) / 3
chunks = cfg.n_cap // cfg.chunk
per_dev = -(-chunks // n) * cfg.chunk
psum_bytes = (cfg.ninc * ig.dim * 2 + cfg.n_cubes * 2) * 4
print(json.dumps(dict(n=n, wall=dt, per_dev_evals=per_dev,
                      psum_bytes=psum_bytes, mean=float(r.cube_s1.sum()))))
"""


def run(fast=True):
    devs = [1, 2, 4, 8]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    base = None
    for n in devs:
        out = subprocess.run([sys.executable, "-c", _WORKER, str(n)],
                             env=env, capture_output=True, text=True,
                             timeout=600)
        if out.returncode != 0:
            emit(f"table8/gpus={n}", 0.0, f"ERROR {out.stderr[-200:]}")
            continue
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        base = base or rec
        emit(f"table8/devices={n}", rec["wall"],
             f"per_dev_evals={rec['per_dev_evals']} "
             f"psum_bytes={rec['psum_bytes']} "
             f"work_reduction={base['per_dev_evals']/rec['per_dev_evals']:.2f}x")


if __name__ == "__main__":
    run()
