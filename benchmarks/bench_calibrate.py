"""Cost-model calibration harness (ISSUE 8, DESIGN.md §13).

  PYTHONPATH=src python -m benchmarks.bench_calibrate --out COST_TABLE.json

Runs `repro.engine.autotune.calibrate` — steady-state fill/adapt timings
over the (backend, dim, neval, chunk, tile) calibration grid — fits the
per-class cost coefficients, and writes the device-keyed table that
``make_plan(autotune=True)`` / ``--autotune`` consume (via
``$REPRO_COST_TABLE`` or ``./COST_TABLE.json``).  Each measured grid point
is also emitted as a ``calibrate/*`` CSV/JSON row, so the calibration run
itself lands in the --json artifact next to BENCH_*.json.

Inside the suite harness (``benchmarks.run --only calibrate``) the table is
written to COST_TABLE.json in the working directory.
"""

from __future__ import annotations

import argparse
import sys

from .common import emit


def _emit_sample(name: str, sample: dict) -> None:
    emit(name, sample["seconds"],
         f"n_cap={sample['n_cap']} n_chunks={sample['n_chunks']}",
         backend=sample["class"].split("|")[0], chunk=sample["chunk"],
         tile=sample["tile"], n_eval=sample["neval"], dim=sample["d"])


def run(fast=True, out: str = "COST_TABLE.json", backends=None):
    from repro.engine import autotune

    table = autotune.calibrate(fast=fast, backends=backends,
                               emit=_emit_sample)
    table.save(out)
    for key, c in sorted(table.classes.items()):
        print(f"# {key}: c_fixed={c.c_fixed:.3g}s "
              f"c_eval_dim={c.c_eval_dim:.3g} c_chunk={c.c_chunk:.3g} "
              f"c_tile_step={c.c_tile_step:.3g} "
              f"iter_overhead={c.iter_overhead_s:.3g}s "
              f"({c.n_samples} samples)", file=sys.stderr)
    print(f"# wrote {out} ({table.device_kind}/{table.jax_backend}, "
          f"calibrated in {table.calibration_wall_s:.1f}s)", file=sys.stderr)
    return table


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="COST_TABLE.json",
                    help="where to write the fitted cost table")
    ap.add_argument("--full", action="store_true",
                    help="the full calibration grid (default: the fast grid "
                         "— ~a minute on one CPU core)")
    ap.add_argument("--backends", default=None,
                    help="comma-separated registry backends (default: all)")
    args = ap.parse_args(argv)
    backends = (tuple(filter(None, args.backends.split(",")))
                if args.backends else None)
    return run(fast=not args.full, out=args.out, backends=backends)


if __name__ == "__main__":
    main()
