"""Paper Table 1: running-time breakdown of the algorithm sections
(init / map+fill / update / results) for an easy (Roos&Arnold) and an
intensive (Ridge) integrand, across n_eval scales.

cuVegas' finding: fill dominates (36-99%) and grows with n_eval; everything
else amortizes.  Same decomposition measured on the JAX engine.

This module also carries the fill perf trajectory (DESIGN.md §7): the
``.../fill_pallas`` vs ``.../fill_fused`` rows time the P-V2 baseline kernel
against the P-V3 streaming kernel at the smoke shapes, and ``.../fill_gpu``
adds the Triton-lowered scatter kernel (DESIGN.md §14) — the numbers behind
BENCH_fill.json, the CI bench gate (``benchmarks.run --gate-fill``) and the
absolute trajectory gate (``--gate-abs``).  The pallas comparison uses
closure-free integrands only: a traced integrand that captures arrays
(e.g. ridge's peak table) cannot be inlined into a pallas kernel body.

The ``table1/phases/*`` rows decompose one fill into its phases so the
accumulation rewrite is attributable per backend without real-GPU access:
``rng`` (chunk-keyed uniform generation), ``eval`` (transform + integrand),
and ``adapt`` (map + stratification update) are measured directly and are
backend-independent at the JAX level; ``accumulate/<backend>`` is measured
directly for ``ref`` (the scatter-add program) and derived as
``total - rng - eval`` for the pallas backends, whose accumulation happens
inside the kernel and cannot be timed in isolation.
"""

from __future__ import annotations

import functools
import time

import jax

from repro import kernels
from repro.core import integrator as I
from repro.core import fill as F
from repro.core import map as vmap_
from repro.core import strat
from repro.core.integrands import make_cosine, make_ridge, make_roos_arnold
from .common import emit, timeit


def _sections(ig, neval):
    cfg = I.VegasConfig(neval=neval, max_it=4, ninc=1024,
                        chunk=min(neval, 1 << 14)).resolve(ig.dim)
    t0 = time.perf_counter()
    state = I.init_state(ig, cfg, jax.random.PRNGKey(0))
    jax.block_until_ready(state.edges)
    t_init = time.perf_counter() - t0

    fill_j = jax.jit(functools.partial(
        F.fill_reference, integrand=ig, nstrat=cfg.nstrat, n_cap=cfg.n_cap,
        chunk=cfg.chunk))
    key = jax.random.fold_in(state.key, 0)
    res = jax.block_until_ready(fill_j(state.edges, state.n_h, key))  # compile
    t0 = time.perf_counter()
    res = jax.block_until_ready(fill_j(state.edges, state.n_h, key))
    t_fill = time.perf_counter() - t0

    upd_j = jax.jit(lambda e, r, d: (
        vmap_.adapt_edges(e, r.map_sums, r.map_counts, 0.5),
        strat.adapt_nh(d, 0.75, cfg.neval)))
    _, _, d_h = F.estimate_from_cubes(res, state.n_h)
    jax.block_until_ready(upd_j(state.edges, res, d_h))
    t0 = time.perf_counter()
    jax.block_until_ready(upd_j(state.edges, res, d_h))
    t_update = time.perf_counter() - t0

    res_j = jax.jit(lambda r, nh: F.estimate_from_cubes(r, nh)[:2])
    jax.block_until_ready(res_j(res, state.n_h))
    t0 = time.perf_counter()
    jax.block_until_ready(res_j(res, state.n_h))
    t_results = time.perf_counter() - t0

    total = t_init + t_fill + t_update + t_results
    return dict(init=t_init, fill=t_fill, update=t_update, results=t_results,
                total=total)


def _fill_backends(ig, neval, ninc=1024):
    """Time the fill implementations on identical (edges, n_h, key):
    reference, pallas baseline (P-V2), pallas fused (P-V3), pallas-gpu
    (Triton scatter).  Tiles/blocks come from each kernel's own static
    autotuner; interpret mode resolves per platform and kernel family."""
    cfg = I.VegasConfig(neval=neval, ninc=ninc,
                        chunk=min(neval, 1 << 14)).resolve(ig.dim)
    state = I.init_state(ig, cfg, jax.random.PRNGKey(0))
    key = jax.random.fold_in(state.key, 0)

    def jitted(fn, **kw):
        return jax.jit(functools.partial(
            fn, integrand=ig, nstrat=cfg.nstrat, n_cap=cfg.n_cap,
            chunk=cfg.chunk, **kw))

    t_ref = timeit(jitted(F.fill_reference), state.edges, state.n_h, key)
    t_base = timeit(jitted(F.fill_pallas, fused_cubes=False),
                    state.edges, state.n_h, key)
    t_fused = timeit(jitted(F.fill_pallas, fused_cubes=True),
                     state.edges, state.n_h, key)
    t_gpu = timeit(jitted(F.fill_pallas_gpu),
                   state.edges, state.n_h, key)
    return t_ref, t_base, t_fused, t_gpu


def _phases(ig, neval, ninc=1024):
    """Per-phase fill decomposition (module docstring): returns measured
    ``rng``/``eval``/``adapt`` seconds plus per-backend ``accumulate``
    (direct for ref, ``total - rng - eval`` for the in-kernel backends)."""
    import jax.numpy as jnp

    cfg = I.VegasConfig(neval=neval, ninc=ninc,
                        chunk=min(neval, 1 << 14)).resolve(ig.dim)
    state = I.init_state(ig, cfg, jax.random.PRNGKey(0))
    key = jax.random.fold_in(state.key, 0)
    dim, chunk, n_chunks = ig.dim, cfg.chunk, cfg.n_cap // cfg.chunk

    def scan(body):
        def prog(k):
            def step(c, g):
                return c + body(k, g), None
            out, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32),
                                  jnp.arange(n_chunks))
            return out
        return jax.jit(prog)

    # rng: the chunk-keyed uniform stream every backend consumes (the
    # in-kernel backends regenerate exactly this inside the kernel).
    t_rng = timeit(scan(lambda k, g: jnp.sum(jax.random.uniform(
        jax.random.fold_in(k, g), (chunk, dim)))), key)

    # eval: transform + integrand on that stream (map lookup + jacobian).
    def eval_body(k, g):
        u = jax.random.uniform(jax.random.fold_in(k, g), (chunk, dim))
        cube = strat.cubes_for_slice(state.n_h, g * chunk, chunk)
        w, _, _ = F._eval_chunk(state.edges, cube, u, ig, cfg.nstrat,
                                cfg.n_cubes)
        return jnp.sum(w)
    t_eval = timeit(scan(eval_body), key)

    # accumulate/ref: the scatter-add program on precomputed (w, iy, cube).
    def acc_body(k, g):
        u = jax.random.uniform(jax.random.fold_in(k, g), (chunk, dim))
        cube = strat.cubes_for_slice(state.n_h, g * chunk, chunk)
        w, iy, valid = F._eval_chunk(state.edges, cube, u, ig, cfg.nstrat,
                                     cfg.n_cubes)
        ms, _ = vmap_.accumulate_map_weights(iy, w * w,
                                             valid.astype(w.dtype), cfg.ninc)
        s1 = jnp.zeros((cfg.n_cubes + 1,), w.dtype).at[cube].add(w)
        return jnp.sum(ms) + jnp.sum(s1)
    t_acc_ref = max(timeit(scan(acc_body), key) - t_eval, 0.0)

    # adapt: map + stratification update (backend-independent).
    fill_j = jax.jit(functools.partial(
        F.fill_reference, integrand=ig, nstrat=cfg.nstrat, n_cap=cfg.n_cap,
        chunk=cfg.chunk))
    res = jax.block_until_ready(fill_j(state.edges, state.n_h, key))
    _, _, d_h = F.estimate_from_cubes(res, state.n_h)
    t_adapt = timeit(jax.jit(lambda e, r, d: (
        vmap_.adapt_edges(e, r.map_sums, r.map_counts, 0.5),
        strat.adapt_nh(d, 0.75, cfg.neval))), state.edges, res, d_h)

    # accumulate/<pallas backend>: derived from each backend's fill total.
    t_ref, t_base, t_fused, t_gpu = _fill_backends(ig, neval, ninc=ninc)
    acc = {"ref": t_acc_ref,
           "pallas-fused": max(t_fused - t_rng - t_eval, 0.0),
           "pallas-gpu": max(t_gpu - t_rng - t_eval, 0.0)}
    return dict(rng=t_rng, eval=t_eval, adapt=t_adapt, accumulate=acc)


def run(fast=True):
    evals = [10**5, 10**6] if fast else [10**5, 10**6, 10**7]
    for name, mk in [("roos_arnold", make_roos_arnold),
                     ("ridge", lambda: make_ridge(n_peaks=1000))]:
        ig = mk()
        for ne in evals:
            s = _sections(ig, ne)
            pct = {k: 100 * v / s["total"] for k, v in s.items() if k != "total"}
            emit(f"table1/{name}/neval={ne:.0e}/fill", s["fill"],
                 f"fill%={pct['fill']:.1f} init%={pct['init']:.1f} "
                 f"update%={pct['update']:.1f} results%={pct['results']:.1f}",
                 n_eval=ne, backend="ref")

    # Fill perf trajectory: P-V2 baseline vs P-V3 fused vs the Triton
    # scatter kernel at the smoke shapes (full mode adds a second decade).
    pallas_evals = [10**5] if fast else [10**5, 10**6]
    # A BENCH_fill.json row is only comparable to rows that ran the kernel
    # the same way: record the resolved interpret mode (platform autodetect,
    # kernels.resolve_interpret, per kernel family) in every pallas-backed
    # fill row, so trajectory tooling never pits an interpreter number
    # against a compiled one.
    interp = kernels.resolve_interpret(None)
    interp_gpu = kernels.resolve_interpret(None, family="gpu")
    for name, ig in [("roos_arnold", make_roos_arnold()),
                     ("cosine_d6", make_cosine(dim=6))]:
        for ne in pallas_evals:
            t_ref, t_base, t_fused, t_gpu = _fill_backends(ig, ne)
            emit(f"table1/{name}/neval={ne:.0e}/fill_pallas", t_base,
                 f"vs_ref={t_ref / t_base:.3f}x", n_eval=ne, backend="pallas",
                 interpret=interp)
            emit(f"table1/{name}/neval={ne:.0e}/fill_fused", t_fused,
                 f"speedup_vs_pallas={t_base / t_fused:.2f}x",
                 n_eval=ne, backend="pallas_fused", interpret=interp)
            emit(f"table1/{name}/neval={ne:.0e}/fill_gpu", t_gpu,
                 f"vs_ref={t_ref / t_gpu:.3f}x "
                 f"vs_fused={t_fused / t_gpu:.3f}x",
                 n_eval=ne, backend="pallas_gpu", interpret=interp_gpu)

    # Per-phase decomposition (one smoke shape: the phases suite re-times
    # every backend's full fill, so keep its footprint to one integrand).
    ig = make_roos_arnold()
    ne = pallas_evals[0]
    ph = _phases(ig, ne)
    for phase in ("rng", "eval", "adapt"):
        emit(f"table1/phases/roos_arnold/neval={ne:.0e}/{phase}", ph[phase],
             "backend-independent (JAX-level)", n_eval=ne)
    for backend, t in ph["accumulate"].items():
        how = ("measured scatter-add program" if backend == "ref"
               else "derived: fill_total - rng - eval")
        emit(f"table1/phases/roos_arnold/neval={ne:.0e}/accumulate/{backend}",
             t, how, n_eval=ne, backend=backend,
             interpret=(None if backend == "ref"
                        else interp_gpu if backend == "pallas-gpu"
                        else interp))


if __name__ == "__main__":
    run()
