"""Paper Table 1: running-time breakdown of the algorithm sections
(init / map+fill / update / results) for an easy (Roos&Arnold) and an
intensive (Ridge) integrand, across n_eval scales.

cuVegas' finding: fill dominates (36-99%) and grows with n_eval; everything
else amortizes.  Same decomposition measured on the JAX engine.

This module also carries the fill perf trajectory (DESIGN.md §7): the
``.../fill_pallas`` vs ``.../fill_fused`` rows time the P-V2 baseline kernel
against the P-V3 streaming kernel at the smoke shapes — the numbers behind
BENCH_fill.json and the CI bench gate (``benchmarks.run --gate-fill``).
The pallas comparison uses closure-free integrands only: a traced integrand
that captures arrays (e.g. ridge's peak table) cannot be inlined into a
pallas kernel body.
"""

from __future__ import annotations

import functools
import time

import jax

from repro import kernels
from repro.core import integrator as I
from repro.core import fill as F
from repro.core import map as vmap_
from repro.core import strat
from repro.core.integrands import make_cosine, make_ridge, make_roos_arnold
from .common import emit, timeit


def _sections(ig, neval):
    cfg = I.VegasConfig(neval=neval, max_it=4, ninc=1024,
                        chunk=min(neval, 1 << 14)).resolve(ig.dim)
    t0 = time.perf_counter()
    state = I.init_state(ig, cfg, jax.random.PRNGKey(0))
    jax.block_until_ready(state.edges)
    t_init = time.perf_counter() - t0

    fill_j = jax.jit(functools.partial(
        F.fill_reference, integrand=ig, nstrat=cfg.nstrat, n_cap=cfg.n_cap,
        chunk=cfg.chunk))
    key = jax.random.fold_in(state.key, 0)
    res = jax.block_until_ready(fill_j(state.edges, state.n_h, key))  # compile
    t0 = time.perf_counter()
    res = jax.block_until_ready(fill_j(state.edges, state.n_h, key))
    t_fill = time.perf_counter() - t0

    upd_j = jax.jit(lambda e, r, d: (
        vmap_.adapt_edges(e, r.map_sums, r.map_counts, 0.5),
        strat.adapt_nh(d, 0.75, cfg.neval)))
    _, _, d_h = F.estimate_from_cubes(res, state.n_h)
    jax.block_until_ready(upd_j(state.edges, res, d_h))
    t0 = time.perf_counter()
    jax.block_until_ready(upd_j(state.edges, res, d_h))
    t_update = time.perf_counter() - t0

    res_j = jax.jit(lambda r, nh: F.estimate_from_cubes(r, nh)[:2])
    jax.block_until_ready(res_j(res, state.n_h))
    t0 = time.perf_counter()
    jax.block_until_ready(res_j(res, state.n_h))
    t_results = time.perf_counter() - t0

    total = t_init + t_fill + t_update + t_results
    return dict(init=t_init, fill=t_fill, update=t_update, results=t_results,
                total=total)


def _fill_backends(ig, neval, ninc=1024):
    """Time the three fill implementations on identical (edges, n_h, key):
    reference, pallas baseline (P-V2), pallas fused (P-V3).  Tiles come from
    the VMEM-budget autotuner; interpret mode resolves per platform."""
    cfg = I.VegasConfig(neval=neval, ninc=ninc,
                        chunk=min(neval, 1 << 14)).resolve(ig.dim)
    state = I.init_state(ig, cfg, jax.random.PRNGKey(0))
    key = jax.random.fold_in(state.key, 0)

    def jitted(fn, **kw):
        return jax.jit(functools.partial(
            fn, integrand=ig, nstrat=cfg.nstrat, n_cap=cfg.n_cap,
            chunk=cfg.chunk, **kw))

    t_ref = timeit(jitted(F.fill_reference), state.edges, state.n_h, key)
    t_base = timeit(jitted(F.fill_pallas, fused_cubes=False),
                    state.edges, state.n_h, key)
    t_fused = timeit(jitted(F.fill_pallas, fused_cubes=True),
                     state.edges, state.n_h, key)
    return t_ref, t_base, t_fused


def run(fast=True):
    evals = [10**5, 10**6] if fast else [10**5, 10**6, 10**7]
    for name, mk in [("roos_arnold", make_roos_arnold),
                     ("ridge", lambda: make_ridge(n_peaks=1000))]:
        ig = mk()
        for ne in evals:
            s = _sections(ig, ne)
            pct = {k: 100 * v / s["total"] for k, v in s.items() if k != "total"}
            emit(f"table1/{name}/neval={ne:.0e}/fill", s["fill"],
                 f"fill%={pct['fill']:.1f} init%={pct['init']:.1f} "
                 f"update%={pct['update']:.1f} results%={pct['results']:.1f}",
                 n_eval=ne, backend="ref")

    # Fill perf trajectory: P-V2 baseline vs P-V3 fused at the smoke shapes
    # (full mode adds a second n_eval decade).
    pallas_evals = [10**5] if fast else [10**5, 10**6]
    # A BENCH_fill.json row is only comparable to rows that ran the kernel
    # the same way: record the resolved interpret mode (platform autodetect,
    # kernels.backend_default) in every pallas-backed fill row, so trajectory
    # tooling never pits an interpreter number against a compiled one.
    interp = kernels.backend_default() == "interpret"
    for name, ig in [("roos_arnold", make_roos_arnold()),
                     ("cosine_d6", make_cosine(dim=6))]:
        for ne in pallas_evals:
            t_ref, t_base, t_fused = _fill_backends(ig, ne)
            emit(f"table1/{name}/neval={ne:.0e}/fill_pallas", t_base,
                 f"vs_ref={t_ref / t_base:.3f}x", n_eval=ne, backend="pallas",
                 interpret=interp)
            emit(f"table1/{name}/neval={ne:.0e}/fill_fused", t_fused,
                 f"speedup_vs_pallas={t_base / t_fused:.2f}x",
                 n_eval=ne, backend="pallas_fused", interpret=interp)


if __name__ == "__main__":
    run()
