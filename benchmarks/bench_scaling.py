"""Paper Fig. 3: fill-phase scaling in (a) batch/chunk size, (b) number of
map intervals, (c) dimensions, (d) number of evaluations.  Single-parameter
sweeps around the paper's default operating point, on the jitted fill."""

from __future__ import annotations

import functools

import jax

from repro.core import fill as F
from repro.core import integrator as I
from repro.core.integrands import make_linear
from .common import emit, timeit


def _fill_time(ig, neval, ninc, chunk):
    cfg = I.VegasConfig(neval=neval, ninc=ninc,
                        chunk=min(chunk, neval)).resolve(ig.dim)
    st = I.init_state(ig, cfg, jax.random.PRNGKey(0))
    f = jax.jit(functools.partial(F.fill_reference, integrand=ig,
                                  nstrat=cfg.nstrat, n_cap=cfg.n_cap,
                                  chunk=cfg.chunk))
    key = jax.random.fold_in(st.key, 0)
    return timeit(f, st.edges, st.n_h, key, repeats=3, warmup=1)


def run(fast=True):
    base_ne = 2 * 10**5 if fast else 2 * 10**6
    # (a) chunk ("batch_size")
    for chunk in (1 << 12, 1 << 14, 1 << 16):
        t = _fill_time(make_linear(10), base_ne, 1024, chunk)
        emit(f"fig3a/chunk={chunk}", t, f"evals_per_s={base_ne/t:,.0f}")
    # (b) intervals
    for ninc in (16, 256, 1024, 4096):
        t = _fill_time(make_linear(10), base_ne, ninc, 1 << 14)
        emit(f"fig3b/ninc={ninc}", t, f"evals_per_s={base_ne/t:,.0f}")
    # (c) dimensions
    for d in (2, 4, 8, 16):
        t = _fill_time(make_linear(d), base_ne, 1024, 1 << 14)
        emit(f"fig3c/dim={d}", t, f"evals_per_s={base_ne/t:,.0f}")
    # (d) evaluations
    for ne in (base_ne // 10, base_ne, base_ne * 4):
        t = _fill_time(make_linear(10), ne, 1024, 1 << 14)
        emit(f"fig3d/neval={ne:.0e}", t, f"evals_per_s={ne/t:,.0f}")


if __name__ == "__main__":
    run()
