"""Load generator for the sweep service (ISSUE 7, DESIGN.md §12):
requests/sec at a fixed precision target, coalesced micro-batching vs
serial per-request execution of the SAME burst.

The claim to reproduce: a burst of small compatible requests is
overhead-bound — per-request dispatch (trace lookup, host round-trips,
B-1 extra program launches) dominates the device work — so coalescing the
burst into ONE vmapped program beats running each request alone.  The
serial baseline is the service itself at ``max_batch=1`` (same admission,
same program cache, same billing — the ONLY difference is coalescing), so
the ratio isolates the micro-batcher.

Standalone (the CI serve-smoke job drives this):

  PYTHONPATH=src python -m benchmarks.bench_serve --burst 8 \
      --out BENCH_serve.json --check

``--check`` asserts every request met its precision target or was stopped
by its time budget, and that the coalesced burst beat the serial one.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from .common import emit, git_sha

# The serving shape: many SMALL requests (the overhead-bound regime where
# coalescing pays — per-request planning/dispatch dominates device work),
# with a precision target every scenario meets at the same iteration.  A
# target lanes meet at DIFFERENT iterations would charge the coalesced
# batch the worst lane's trip count (masked no-op iterations still burn
# compute on one core) — that regime needs lane-parallel hardware, where
# the vmapped program wins on throughput instead.
RTOL = 0.2
KW = dict(neval=500, max_it=8, ninc=32, chunk=500)


def _burst(n: int, seed0: int = 0):
    """n compatible single-scenario requests: one class, distinct params,
    per-request RNG streams; the back half carries a (generous) wall-clock
    budget so the budget path is exercised under load."""
    from repro.serve import IntegrationRequest
    return [IntegrationRequest(
        family="gaussian",
        params=[0.2 + 0.6 * i / max(n - 1, 1)],
        rtol=RTOL, seed=seed0 + i,
        time_budget_s=(60.0 if i >= n // 2 else None),
        **KW) for i in range(n)]


def _serve_burst(n: int, max_batch: int, repeats: int = 2):
    """Serve the n-request burst through a fresh service; best-of-repeats
    wall clock AFTER a same-shape warm-up burst (trace+compile excluded,
    exactly what a long-lived service amortizes)."""
    from repro.serve import SweepService
    svc = SweepService(max_batch=max_batch)
    for r in _burst(n, seed0=10_000):
        svc.submit(r)
    svc.drain()
    wall, results = float("inf"), None
    for rep in range(repeats):
        reqs = _burst(n, seed0=1 + rep * n)
        t0 = time.perf_counter()
        tickets = [svc.submit(r) for r in reqs]
        svc.drain()
        results = [t.result(0) for t in tickets]
        wall = min(wall, time.perf_counter() - t0)
    return wall, results, svc.stats()


def _met(r) -> bool:
    """A served request is within SLA if it hit its precision target or
    its time budget stopped it first."""
    if r.met_precision is not None and bool(r.met_precision.all()):
        return True
    return r.capped


def _bench_burst(n: int):
    """Serve one n-request burst both ways and emit the two rows.
    Returns ``(speedup, wall_coalesced, wall_serial, results)``."""
    wall_c, res_c, stats_c = _serve_burst(n, max_batch=n)
    wall_s, res_s, stats_s = _serve_burst(n, max_batch=1)
    speedup = wall_s / wall_c
    knobs = dict(backend="ref", chunk=KW["chunk"], tile=None, interpret=None)
    emit(f"serve/burst={n}/coalesced", wall_c,
         f"speedup={speedup:.2f}x req_per_s={n / wall_c:.1f}",
         n_requests=n, max_batch=n, rtol=RTOL,
         requests_per_s=round(n / wall_c, 2),
         mean_occupancy=stats_c["batches"]["mean_occupancy"],
         met_sla=sum(_met(r) for r in res_c), **knobs)
    emit(f"serve/burst={n}/serial", wall_s,
         f"req_per_s={n / wall_s:.1f}",
         n_requests=n, max_batch=1, rtol=RTOL,
         requests_per_s=round(n / wall_s, 2),
         mean_occupancy=stats_s["batches"]["mean_occupancy"],
         met_sla=sum(_met(r) for r in res_s), **knobs)
    return speedup, wall_c, wall_s, res_c + res_s


def run(fast=True):
    for n in (16,) if fast else (8, 16, 32):
        _bench_burst(n)


def main(argv=None) -> None:
    from .common import ROWS, reset_rows

    ap = argparse.ArgumentParser()
    ap.add_argument("--burst", type=int, default=16)
    ap.add_argument("--out", default=None, metavar="BENCH_serve.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless every request met its "
                         "precision target or time budget AND the "
                         "coalesced burst beat the serial one")
    args = ap.parse_args(argv)

    reset_rows()
    speedup, wall_c, wall_s, results = _bench_burst(args.burst)

    if args.out:
        import jax
        with open(args.out, "w") as f:
            json.dump({"git_sha": git_sha(), "jax_version": jax.__version__,
                       "jax_backend": jax.default_backend(),
                       "rows": list(ROWS)}, f, indent=1)
        print(f"# wrote {args.out}", file=sys.stderr)

    if args.check:
        missed = [r for r in results if not _met(r)]
        for r in missed:
            print(f"CHECK: {r!r} met neither precision nor budget",
                  file=sys.stderr)
        if missed:
            sys.exit(2)
        if speedup <= 1.0:
            print(f"CHECK: coalesced burst ({wall_c * 1e3:.0f}ms) not "
                  f"faster than serial ({wall_s * 1e3:.0f}ms)",
                  file=sys.stderr)
            sys.exit(2)
        print(f"# serve check OK: {len(results)} requests in SLA, "
              f"coalesced {speedup:.2f}x over serial", file=sys.stderr)


if __name__ == "__main__":
    main()
