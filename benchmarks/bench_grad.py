"""Grad-vs-primal overhead: what a differentiable run costs over a plain
one (§11).

Rows land in BENCH_run.json (the ``run/`` prefix) so the grad overhead
rides the same end-to-end trajectory artifact as the backend timings:

  * ``run/grad/<name>/primal``  — the plain two-phase-free `core.run`;
  * ``run/grad/<name>/value``   — the two-phase program, value only
    (adapt + frozen-map eval, no differentiation);
  * ``run/grad/<name>/grad``    — jax.grad of the full run (the vjp adds
    one reverse pass through the reference eval formulation);
  * ``run/grad/greeks/batch``   — the vmapped family Greeks program
    (per-scenario vjp + with_sdev derivative-integrand passes).

The derived column records the overhead ratio against the primal row.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.batch.family import make_asian_greeks_family
from repro.core import VegasConfig
from repro.core.integrands import Integrand
from repro.engine import ExecutionConfig, GradPolicy, execute, make_plan
from repro.grad import differentiable

from .common import emit, timeit


def run(fast=True):
    neval = 20_000 if fast else 200_000
    max_it = 6 if fast else 12
    cfg = VegasConfig(neval=neval, max_it=max_it, skip=2, ninc=128,
                      chunk=min(neval, 1 << 14))
    key = jax.random.PRNGKey(0)
    dim, sigma = 3, 0.2
    norm = 1.0 / (2.0 * math.pi * sigma**2) ** (dim / 2.0)

    def fn(mu, x):
        return norm * jnp.exp(-jnp.sum((x - mu) ** 2, -1)
                              / (2.0 * sigma**2))

    ig = Integrand("gaussian", dim, lambda x: fn(0.5, x),
                   (0.0,) * dim, (1.0,) * dim)
    # The primal yardstick: the plain adapt loop + combination as ONE
    # jitted program (same dispatch regime as the jitted grad programs —
    # core.run's host-side result assembly would skew the ratio).
    from repro.core import integrator as core
    rcfg = cfg.resolve(dim)

    @jax.jit
    def primal(k):
        st = core.run_loop(core.init_state(ig, rcfg, k), ig, rcfg, 0)
        return core.combine_results(st.results, rcfg.skip, st.it)[:2]

    t_primal = timeit(lambda: primal(key), repeats=3, warmup=1)
    emit("run/grad/gaussian/primal", t_primal,
         f"evals_per_s={neval * max_it / t_primal:,.0f}",
         n_eval=neval, backend="ref", max_it=max_it)

    est = differentiable(fn, dim, (0.0,) * dim, (1.0,) * dim, cfg)
    mu0 = jnp.float32(0.5)
    value = jax.jit(lambda m, k: est(m, k))
    t_value = timeit(lambda: value(mu0, key), repeats=3, warmup=1)
    emit("run/grad/gaussian/value", t_value,
         f"x{t_value / t_primal:.2f} vs primal",
         n_eval=neval, backend="ref", max_it=max_it)

    gradf = jax.jit(jax.grad(lambda m, k: est(m, k)))
    t_grad = timeit(lambda: gradf(mu0, key), repeats=3, warmup=1)
    emit("run/grad/gaussian/grad", t_grad,
         f"x{t_grad / t_primal:.2f} vs primal",
         n_eval=neval, backend="ref", max_it=max_it)

    # The family Greeks program: B scenarios, 2 params each, with_sdev.
    b = 4 if fast else 8
    fam = make_asian_greeks_family(np.linspace(90.0, 110.0, b),
                                   n_steps=4 if fast else 8)
    gcfg = VegasConfig(neval=neval, max_it=max_it, ninc=128,
                       chunk=min(neval, 1 << 14),
                       execution=ExecutionConfig(grad=GradPolicy()))
    plan = make_plan(fam, gcfg)
    t_batch = timeit(lambda: execute(plan, key=key), repeats=3, warmup=1)
    emit("run/grad/greeks/batch", t_batch,
         f"B={b} scenario_grads_per_s={b / t_batch:,.1f}",
         n_eval=neval, backend="ref", max_it=max_it)
