"""Paper Fig. 4 / Table 7: the seven test integrands under the three
parameter configurations (def / vf / tq): wall time vs relative standard
error.  The paper's observation to reproduce: the 'def' configuration gives
the best average accuracy-time tradeoff."""

from __future__ import annotations

import math
import time

import jax

from repro.core import run as vegas_run
from repro.core import VegasConfig
from repro.core.integrands import (make_cosine, make_exponential,
                                   make_gaussian, make_linear,
                                   make_morokoff_caflisch, make_roos_arnold,
                                   make_sine_exp)
from repro.configs.vegas import PAPER_CONFIGS, tq_ninc
from .common import emit

SEVEN = [make_sine_exp, make_linear, make_cosine, make_exponential,
         make_roos_arnold, make_morokoff_caflisch, make_gaussian]


def run(fast=True):
    neval = 100_000 if fast else 1_000_000
    for cname in ("def", "vf", "tq"):
        base = PAPER_CONFIGS[cname]
        rel_errs, times = [], []
        for mk in SEVEN:
            ig = mk()
            ninc = tq_ninc(neval) if cname == "tq" else base.ninc
            cfg = VegasConfig(neval=neval, max_it=12, skip=4, ninc=ninc,
                              alpha=base.alpha, beta=base.beta,
                              chunk=min(neval, 1 << 14))
            t0 = time.perf_counter()
            r = vegas_run(ig, cfg, key=jax.random.PRNGKey(1))
            dt = time.perf_counter() - t0
            rel = abs(r.sdev / r.mean) if r.mean else float("inf")
            rel_errs.append(max(rel, 1e-12))
            times.append(dt)
        gm_err = math.exp(sum(math.log(e) for e in rel_errs) / len(rel_errs))
        gm_time = math.exp(sum(math.log(t) for t in times) / len(times))
        emit(f"table7/config={cname}", gm_time,
             f"geomean_rel_err={gm_err:.3e} neval={neval}")


if __name__ == "__main__":
    run()
