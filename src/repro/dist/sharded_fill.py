"""Sharded fill: distribute the fill phase's chunk axis over a JAX mesh.

The unit of distribution is the *global chunk index* that already keys the
fill's RNG (core/fill.py, DESIGN.md C5): chunk ``g`` draws its uniforms from
``fold_in(key_it, g)`` and finds its hypercubes from the global eval offset
``g * chunk``, so the numbers a shard produces are a pure function of
``(key, g)`` — independent of which device computes them, how many devices
exist, or in what order shards run.  Sharding is therefore just a static
partition of ``range(n_cap // chunk)``:

  * every shard owns the same *static* number of chunks (ceil division), so
    the scanned per-shard program is identical everywhere (no divergence,
    the paper's C1 balance applied across devices);
  * ranges that extend past the real chunk count contribute exactly zero —
    their evals land in the overflow cube bucket and are masked (C2) — so
    uneven shard counts need no special casing;
  * per-shard partials are one psum away from the global
    :class:`~repro.core.fill.FillResult`; the reduced accumulators are
    O(d*ninc + n_cubes) regardless of ``neval`` (the Amdahl argument behind
    the paper's 0.85 efficiency at 8 GPUs, Table 8).

Device-count invariance (checked by tests/_dist_worker.py at rtol 2e-5: the
tolerance covers float32 reduction-order differences only, the sampled
streams are bit-identical) is what makes elastic restart (checkpoint.py) and
straggler re-dispatch (:func:`recompute_shard`, DESIGN.md D3/§5) safe.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6: shard_map graduated out of experimental
    from jax import shard_map as _shard_map
except ImportError:  # jax <= 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core import fill as fill_mod


def mesh_shard_count(mesh, axis_names) -> int:
    """Number of fill shards = product of the mesh extents being sharded over."""
    n = 1
    for a in axis_names:
        n *= mesh.shape[a]
    return n


def shard_chunk_range(total_chunks: int, shard: int, n_shards: int):
    """Contiguous chunk range ``[start, start + count)`` owned by ``shard``.

    Every shard gets the same static ``count`` (ceil division) so all devices
    compile and run the identical scanned program; shards whose range extends
    past ``total_chunks`` simply accumulate zeros there (overflow-bucket
    masking, DESIGN.md C2).  Ranges partition ``[0, n_shards * count)`` and
    are disjoint, so summing every shard's partial reproduces the global fill.
    """
    count = -(-total_chunks // n_shards)
    return shard * count, count


def _shard_fill_callable(resolved_cfg, backend: str | None):
    """The per-shard fill with everything bound except the chunk range.

    ``backend=None`` follows the config's own backend.  Both backends share
    the chunk-keyed RNG contract (bit-identical streams) and accept
    ``start_chunk``/``n_chunks`` + ``kahan``, so sharding is backend-blind;
    the pallas path additionally gets its kernel knobs from the config
    (interpret autodetect, P-V3 fusion, tile autotune).
    """
    rc = resolved_cfg
    backend = rc.backend if backend is None else backend
    kw = dict(nstrat=rc.nstrat, n_cap=rc.n_cap, chunk=rc.chunk,
              dtype=jnp.dtype(rc.dtype), kahan=True)
    if backend == "pallas":
        kw.update(interpret=rc.interpret, fused_cubes=rc.fused_cubes,
                  tile=rc.tile)
    return functools.partial(fill_mod.BACKENDS[backend], **kw)


def make_sharded_fill(mesh, axis_names, resolved_cfg, backend: str | None = None):
    """Build a drop-in ``fill_fn`` for ``core.integrator.iteration_step``.

    ``fill_fn(edges, n_h, key, integrand)`` shard_maps the configured fill
    backend (``'ref'`` or ``'pallas'``; default: the config's own) over the
    mesh axes named in ``axis_names`` (1D or 2D meshes: shards are enumerated
    in row-major order over the named axes) and psum-reduces the per-shard
    :class:`FillResult` partials, returning the same replicated result on
    every device.  Works eagerly and under jit (``run`` jits the whole
    iteration around it, so adaptation stays on-device, C4/C6).
    """
    rc = resolved_cfg
    axis_names = tuple(axis_names)
    n_shards = mesh_shard_count(mesh, axis_names)
    total_chunks = rc.n_cap // rc.chunk
    _, per_shard = shard_chunk_range(total_chunks, 0, n_shards)
    shard_fill = _shard_fill_callable(rc, backend)

    def fill_fn(edges, n_h, key, integrand):
        def body(edges, n_h, key):
            idx = jnp.zeros((), jnp.int32)
            for a in axis_names:  # row-major linear shard index
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
            part = shard_fill(edges, n_h, key, integrand,
                              start_chunk=idx * per_shard, n_chunks=per_shard)
            return jax.tree.map(lambda x: jax.lax.psum(x, axis_names), part)

        # check_rep=False: pallas_call has no replication rule under
        # shard_map; the psum above already replicates the result explicitly.
        sharded = _shard_map(body, mesh=mesh, in_specs=(P(), P(), P()),
                             out_specs=P(), check_rep=False)
        return sharded(edges, n_h, key)

    return fill_fn


def recompute_shard(edges, n_h, key, integrand, resolved_cfg, shard: int,
                    n_shards: int, backend: str | None = None) -> fill_mod.FillResult:
    """Recompute one shard's partial locally — no mesh required.

    The straggler / failure re-dispatch hook (DESIGN.md D3/§5): because the
    RNG is keyed by global chunk id, any host can recompute shard ``shard``
    of an ``n_shards``-way fill and get bit-identical samples to what the
    straggling device would have produced — with either backend, since the
    streams are shared bit-for-bit.  Summing all shards' partials equals the
    unsharded fill (checked by tests/_dist_worker.py check 5).
    """
    rc = resolved_cfg
    start, count = shard_chunk_range(rc.n_cap // rc.chunk, shard, n_shards)
    return _shard_fill_callable(rc, backend)(
        edges, n_h, key, integrand, start_chunk=start, n_chunks=count)
