"""Sharded fill: distribute the fill phase's chunk axis over a JAX mesh.

Thin adapter over the engine's sharding layer (`repro.engine.sharding`,
DESIGN.md §5/§9): the unit of distribution is the *global chunk index* that
already keys the fill's RNG (core/fill.py, C5) — chunk ``g`` draws its
uniforms from ``fold_in(key_it, g)`` and finds its hypercubes from the
global eval offset ``g * chunk``, so the numbers a shard produces are a pure
function of ``(key, g)``: independent of which device computes them, how
many devices exist, or in what order shards run.  Sharding is a static
partition of ``range(n_cap // chunk)`` plus one psum:

  * every shard owns the same *static* number of chunks (ceil division), so
    the scanned per-shard program is identical everywhere (no divergence,
    the paper's C1 balance applied across devices);
  * ranges that extend past the real chunk count contribute exactly zero —
    their evals land in the overflow cube bucket and are masked (C2) — so
    uneven shard counts need no special casing;
  * per-shard partials are one psum away from the global
    :class:`~repro.core.fill.FillResult`; the reduced accumulators are
    O(d*ninc + n_cubes) regardless of ``neval`` (the Amdahl argument behind
    the paper's 0.85 efficiency at 8 GPUs, Table 8).

Device-count invariance (checked by tests/_dist_worker.py at rtol 2e-5: the
tolerance covers float32 reduction-order differences only, the sampled
streams are bit-identical) is what makes elastic restart (checkpoint.py) and
straggler re-dispatch (:func:`recompute_shard`, DESIGN.md D3/§5) safe.

Early stopping under distribution (DESIGN.md §10): a `StopPolicy` run on a
sharded plan keeps the while_loop's continue decision consistent across
devices by construction.  The single-scenario path evaluates the decision
OUTSIDE the shard_map on the psum-replicated statistics; the sharded batched
path evaluates it inside the shard_map and pmin-agrees it across the mesh
axes (:func:`repro.engine.sharding.make_stop_sync`, re-exported here), so
every shard executes the identical trip count.

Prefer expressing sharding through the plan layer
(``ExecutionConfig(mesh=..., shard_axes=...)``); :func:`make_sharded_fill`
remains the drop-in ``fill_fn`` hook for callers that wire the loop by hand.
"""

from __future__ import annotations

from repro.engine import backends as backends_mod
from repro.engine.sharding import (  # noqa: F401  (re-exported API)
    make_local_fill,
    make_sharded_fill,
    make_stop_sync,
    mesh_shard_count,
    shard_chunk_range,
)

from repro.core import fill as fill_mod


def recompute_shard(edges, n_h, key, integrand, resolved_cfg, shard: int,
                    n_shards: int, backend: str | None = None) -> fill_mod.FillResult:
    """Recompute one shard's partial locally — no mesh required.

    The straggler / failure re-dispatch hook (DESIGN.md D3/§5): because the
    RNG is keyed by global chunk id, any host can recompute shard ``shard``
    of an ``n_shards``-way fill and get bit-identical samples to what the
    straggling device would have produced — with any registered backend,
    since the streams are shared bit-for-bit.  Summing all shards' partials
    equals the unsharded fill (checked by tests/_dist_worker.py check 5).
    """
    rc = resolved_cfg
    start, count = shard_chunk_range(rc.n_cap // rc.chunk, shard, n_shards)
    fill = backends_mod.bind_fill(rc, backend=backend, kahan=True)
    return fill(edges, n_h, key, integrand, start_chunk=start, n_chunks=count)
