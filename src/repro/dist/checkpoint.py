"""Checkpoint / restore for fault-tolerant, elastic VEGAS+ runs.

The checkpoint payload is the :class:`~repro.core.integrator.VegasState`
pytree — O(KB): map edges, per-cube allocation, base key, iteration counter,
per-iteration results (DESIGN.md §5).  Nothing in it references a mesh or a
device count, so a run checkpointed on 2 devices resumes on 8 (or on one):
the sharded fill re-derives every shard's stream from (key, chunk id) alone.

Format: a single ``.npz`` holding the pytree leaves in flatten order plus
``step`` and a JSON ``meta`` blob.  The tree *structure* is not serialized;
``restore(path, like)`` rebuilds against a template pytree, which keeps the
format trivial and the payload inspectable with plain numpy.

Writes are atomic (tmp file + ``os.replace``): a checkpoint either exists
complete or not at all, and ``latest``/``restore_latest`` never see partial
files.  ``CheckpointManager`` adds ``ckpt_<step>.npz`` naming, keep-last-N
retention, and corrupt-file fallback on restore.
"""

from __future__ import annotations

import json
import os
import re

import jax
import jax.numpy as jnp
import numpy as np

_CKPT_RE = re.compile(r"^ckpt_(\d+)\.npz$")


def save(path: str, tree, step: int = 0, meta: dict | None = None) -> str:
    """Atomically write ``tree``'s leaves (+ ``step``, ``meta``) to ``path``."""
    leaves = jax.tree.leaves(tree)
    payload = {f"leaf_{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
    payload["__step__"] = np.asarray(int(step), dtype=np.int64)
    payload["__meta__"] = np.frombuffer(
        json.dumps(meta or {}).encode("utf-8"), dtype=np.uint8)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def restore(path: str, like):
    """Read a checkpoint back into the structure of the ``like`` pytree.

    Returns ``(tree, step, meta)``.  Leaf count must match ``like``; shapes
    come from the file (so a resumed run may later grow e.g. the results
    buffer itself — see ``integrator.run``), but dtypes come from the
    TEMPLATE: a run saved under one ``JAX_ENABLE_X64`` setting must resume
    cleanly under the other, so each float leaf is cast to the template
    leaf's dtype rather than trusting the file's.  (Without the cast, an
    x64-saved f64 edges leaf resumed in an f32 process poisons the whole
    loop carry — every subsequent jitted iteration recompiles or fails on
    the dtype mismatch.)  A leaf whose dtype KIND differs (float saved where
    the template holds int, ...) is structural corruption, not a precision
    flip, and raises ``ValueError`` naming the leaf.
    """
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(like)[0]]
    n_leaves = treedef.num_leaves
    with np.load(path) as z:
        step = int(z["__step__"])
        raw = bytes(z["__meta__"].tobytes())
        meta = json.loads(raw.decode("utf-8")) if raw else {}
        leaves = []
        for i, tmpl in enumerate(like_leaves):
            arr = z[f"leaf_{i}"]
            want = jnp.asarray(tmpl).dtype
            if arr.dtype != want:
                if np.dtype(arr.dtype).kind != np.dtype(want).kind:
                    raise ValueError(
                        f"checkpoint {path!r} leaf {i} ({paths[i] or '<root>'}"
                        f") holds dtype {arr.dtype} where the template has "
                        f"{want} — different kinds, refusing to cast "
                        f"(wrong/corrupt checkpoint for this state?)")
                arr = arr.astype(want)
            leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, leaves), step, meta


def _candidates(ckpt_dir: str):
    """(step, path) for every complete checkpoint in ``ckpt_dir``, newest
    first.  ``.tmp`` leftovers from interrupted writes never match."""
    try:
        names = os.listdir(ckpt_dir)
    except FileNotFoundError:
        return []
    out = []
    for name in names:
        m = _CKPT_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(ckpt_dir, name)))
    return sorted(out, reverse=True)


def latest(ckpt_dir: str) -> str | None:
    """Path of the newest complete checkpoint, or None if there is none."""
    cand = _candidates(ckpt_dir)
    return cand[0][1] if cand else None


class CheckpointManager:
    """``ckpt_<step>.npz`` files in ``dir`` with keep-last-``keep`` retention.

    Wire into a run as ``run(..., checkpoint_cb=lambda it, s: mgr.save(it, s))``;
    resume with ``state, step, meta = mgr.restore_latest(template_state)``.
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        assert keep >= 1, keep
        self.dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)

    def path_for(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{int(step)}.npz")

    def save(self, step: int, tree, meta: dict | None = None) -> str:
        path = save(self.path_for(step), tree, step=step, meta=meta)
        for _, old in _candidates(self.dir)[self.keep:]:
            try:
                os.remove(old)
            except OSError:
                pass  # concurrent cleanup is not an error
        return path

    def restore_latest(self, like):
        """Restore the newest readable checkpoint, falling back past corrupt
        files (a crash mid-retention or a torn copy must not kill the resume).

        Returns None when the directory holds no checkpoints at all (cold
        start); raises FileNotFoundError when
        checkpoints exist but none is readable (data loss must be loud)."""
        cand = _candidates(self.dir)
        if not cand:
            return None
        errors = []
        for step, path in cand:
            try:
                return restore(path, like)
            except Exception as e:  # corrupt/truncated/wrong-arity file
                errors.append(f"{path}: {e!r}")
        raise FileNotFoundError(
            f"no readable checkpoint in {self.dir!r} "
            f"(skipped: {'; '.join(errors)})")
