"""repro.dist: multi-device scale-out for the VEGAS+ fill phase.

Two orthogonal pieces (DESIGN.md §5):
  * :mod:`sharded_fill` — shard the global chunk axis of the fill over a JAX
    mesh (the paper's multi-GPU decomposition, C5, recast as shard_map), with
    a per-shard recompute hook for straggler re-dispatch.
  * :mod:`checkpoint` — save/restore the O(KB) :class:`VegasState` payload so
    a run checkpointed on one device count resumes on another (elastic
    scaling; the payload is mesh-free by construction).
"""

from . import checkpoint, sharded_fill  # noqa: F401
from .checkpoint import CheckpointManager, latest, restore, save  # noqa: F401
from .sharded_fill import (  # noqa: F401
    make_sharded_fill,
    make_stop_sync,
    recompute_shard,
    shard_chunk_range,
)
