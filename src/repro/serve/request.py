"""Request/response types of the sweep service (DESIGN.md §12).

An :class:`IntegrationRequest` is the service's admission unit: it names a
served integrand family, carries the per-scenario parameters of ONE sweep
(a request may hold several scenarios — e.g. four strikes of one book), the
algorithm configuration, a precision target (``rtol``/``atol``), and an
optional wall-clock ``time_budget_s``.  `SweepService.submit` validates the
combination through ``make_plan`` BEFORE anything touches a device and
raises the one-line `PlanError` on rejection.

A :class:`Ticket` is the caller's handle on an admitted request; its
:meth:`Ticket.result` blocks until the micro-batcher has executed the
request and returns a :class:`RequestResult` with per-scenario estimates
and the billing record (each request pays for its own ``n_it_used``
iterations, not for the batch it rode in).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class IntegrationRequest:
    """One integration sweep: a served family, its scenario parameters, and
    the targets the run must meet.

    ``rtol``/``atol`` form the precision target (`StopPolicy` semantics:
    stop once ``sdev <= max(rtol * |mean|, atol)``, never before
    ``min_it``); both 0 means a fixed-length run.  ``time_budget_s`` is the
    wall-clock budget: the service converts it into an iteration-count cap
    from the measured per-iteration cost of this request's compatibility
    class and threads it through the adaptive loop's carry — a hard ceiling
    that wins over ``min_it`` (§12).  ``seed`` pins the request's RNG
    stream: scenario ``j`` draws from ``fold_in(PRNGKey(seed), j)``
    whatever batch the request is coalesced into, so results are invariant
    to micro-batching.

    ``family_kwargs`` (a tuple of ``(name, value)`` pairs, hashable so it
    can join the compatibility key) is forwarded to the family builder —
    e.g. ``(("dim", 6),)`` for a 6-d Gaussian sweep.
    """
    family: str
    params: Any
    rtol: float = 0.0
    atol: float = 0.0
    min_it: int = 2
    time_budget_s: float | None = None
    seed: int = 0
    neval: int = 50_000
    max_it: int = 10
    skip: int = 2
    ninc: int = 128
    alpha: float = 0.5
    beta: float = 0.75
    chunk: int = 16_384
    dtype: str = "float32"
    #: §15 accumulation dtype (None = accumulate in ``dtype``).  Part of the
    #: compatibility key: requests under different precision policies never
    #: coalesce into one program.
    accum_dtype: str | None = None
    backend: str = "ref"
    interpret: bool | None = None
    tile: int | None = None
    family_kwargs: tuple = ()

    @property
    def has_precision_target(self) -> bool:
        return self.rtol > 0.0 or self.atol > 0.0

    def compat_key(self) -> tuple:
        """The micro-batcher's coalescing key: requests sharing it resolve
        to the same family geometry, algorithm config, backend knobs, and
        stop policy — everything that must agree for their scenarios to run
        as extra lanes of ONE vmapped program.  Seeds and time budgets stay
        per-request (per-scenario keys / caps), so they are NOT part of the
        key."""
        return (self.family, tuple(self.family_kwargs), self.neval,
                self.max_it, self.skip, self.ninc, self.alpha, self.beta,
                self.chunk, self.dtype, self.backend, self.interpret,
                self.tile, self.rtol, self.atol, self.min_it,
                self.accum_dtype)


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """Per-scenario estimates + the billing record of one served request."""
    request_id: int
    family: str
    mean: np.ndarray            # (n,) per-scenario estimates
    sdev: np.ndarray            # (n,)
    chi2_dof: np.ndarray        # (n,)
    n_it_used: np.ndarray       # (n,) iterations each scenario executed —
                                # the billing unit (§12)
    targets: np.ndarray | None  # (n,) analytic values where the family has
                                # them
    met_precision: np.ndarray | None  # (n,) bool, None w/o a precision
                                      # target
    it_cap: np.ndarray          # (n,) the iteration cap applied (max_it
                                # when unbounded)
    capped: bool                # any scenario stopped by its time budget
    budget_enforced: bool       # a cost estimate existed, so the cap is
                                # derived from the budget (False on the
                                # calibration batch of a new class)
    billed_iterations: int      # sum(n_it_used) — what this request pays
    billed_evals: int           # billed_iterations * neval (approximate)
    queue_s: float              # submit -> batch execution start
    run_s: float                # the batch's wall clock (shared by every
                                # request coalesced into it)
    batch_id: int
    batch_size: int             # scenarios in the batch this request rode
    warm_started: bool          # maps seeded from the shared MapCache pool

    @property
    def n_scenarios(self) -> int:
        return int(self.mean.shape[0])

    def __repr__(self):
        ok = ("-" if self.met_precision is None
              else f"{int(self.met_precision.sum())}/{self.n_scenarios}")
        return (f"RequestResult(id={self.request_id}, family={self.family}, "
                f"n={self.n_scenarios}, met_precision={ok}, "
                f"billed_it={self.billed_iterations}, "
                f"queue={self.queue_s * 1e3:.1f}ms, "
                f"run={self.run_s * 1e3:.1f}ms)")


class Ticket:
    """Caller-side handle on an admitted request (thread-safe)."""

    def __init__(self, request: IntegrationRequest, request_id: int,
                 family, params: np.ndarray, t_submit: float):
        self.request = request
        self.request_id = request_id
        self.compat_key = request.compat_key()
        self.family = family          # the admission-built IntegrandFamily
        self.params = params          # normalized builder-input params
        self.t_submit = t_submit
        self._event = threading.Event()
        self._result: RequestResult | None = None
        self._error: BaseException | None = None

    @property
    def n_scenarios(self) -> int:
        return int(self.family.batch_size)

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> RequestResult:
        """Block until the micro-batcher has executed this request."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not served within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    def _resolve(self, result: RequestResult) -> None:
        self._result = result
        self._event.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._event.set()
