"""Integration-as-a-service: the queued sweep service (DESIGN.md §12).

`SweepService` multiplexes many integration requests onto shared compute —
the serving layer the ROADMAP's "millions of users" north star asks for,
composed entirely from engine pieces PRs 1–6 built:

  * **admission** — `submit` resolves each request into a (family,
    VegasConfig, ExecutionConfig) combination and validates it with
    ``make_plan`` BEFORE it can touch a device; invalid combinations are
    rejected with the engine's one-line `PlanError`;
  * **micro-batching** — queued requests sharing a compatibility key (same
    family geometry + resolved config + stop policy) coalesce into ONE
    vmapped whole-run program (`engine.executor.make_family_program`) with
    per-scenario stop masks; the compiled program is cached per class, so
    a burst pays trace+compile once, not per request;
  * **warm starts** — importance maps are seeded from a shared
    `batch.cache.MapCache`: the service pools one scenario-averaged map per
    (family, config) class — stored under a batch-size-1 pool key so a hit
    broadcasts to any occupancy — and refreshes it after every batch;
  * **time budgets** — a request's wall-clock budget becomes an
    iteration-count cap (``floor(budget / measured per-iteration cost)``)
    threaded through the adaptive loop's carry (`core.run_loop`); the cost
    model is the engine's shared `engine.autotune.OnlineCost`: min-observed
    per compatibility class from executed batches (the first batch of a
    class calibrates, subsequent ones enforce), optionally seeded with a
    calibrated `CostTable` prior so even a class's first batch is enforced;
  * **billing** — every request pays for its own scenarios' ``n_it_used``,
    not for the batch it rode in;
  * **metrics** — queue/run latency, batch occupancy, cache hit rate, and
    iterations saved, exposed by :meth:`SweepService.stats`.

The service is in-process: drive it synchronously with :meth:`drain`
(tests, benchmarks) or start the background worker thread
(:meth:`start`/:meth:`stop`) that gathers each burst for ``max_wait_s``
and executes it — the long-lived form the `repro.launch.serve` CLI runs.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.batch.cache import MapCache
from repro.batch.engine import scenario_keys
from repro.batch.family import (IntegrandFamily, make_asian_family,
                                make_gaussian_family, make_ridge_family)
from repro.core import integrator as core
from repro.engine import (ExecutionConfig, PlanError, PrecisionPolicy,
                          StopPolicy, make_plan)
from repro.engine import autotune as autotune_mod
from repro.engine import executor as executor_mod

from .metrics import ServeMetrics
from .request import IntegrationRequest, RequestResult, Ticket


@dataclasses.dataclass(frozen=True)
class ServedFamily:
    """A servable integrand family: how to normalize request params and
    build the (possibly coalesced) `IntegrandFamily` from them.
    ``normalize(params, dtype)`` receives the REQUEST's dtype — params must
    come back in it, or the family's vmapped closure constants silently
    promote the whole fill to float64 (the §15 dtype-correctness audit)."""
    name: str
    build: Callable[..., IntegrandFamily]
    normalize: Callable[..., np.ndarray]


def _norm_1d(params, dtype=np.float64) -> np.ndarray:
    return np.atleast_1d(np.asarray(params, dtype))


def _norm_2d(params, dtype=np.float64) -> np.ndarray:
    return np.atleast_2d(np.asarray(params, dtype))


#: The default serving registry: family name -> builder taking ONE
#: positional per-scenario parameter array (scenario axis leading), so the
#: micro-batcher can concatenate requests' params and rebuild.
SERVED_FAMILIES: dict[str, ServedFamily] = {
    "gaussian": ServedFamily("gaussian", make_gaussian_family, _norm_1d),
    "asian": ServedFamily("asian", make_asian_family, _norm_1d),
    "ridge": ServedFamily("ridge", make_ridge_family, _norm_2d),
}


class _PoolKey:
    """Duck-typed (name, batch_size) pair for `batch.cache.cache_key`: the
    service's map pool stores ONE scenario-averaged map per (family,
    config) class under batch size 1, so a hit broadcasts to any
    occupancy."""

    def __init__(self, family_name: str):
        self.name = f"{family_name}@serve-pool"
        self.batch_size = 1


class SweepService:
    """Long-lived queued sweep service over `repro.engine` (§12).

    ``max_batch`` bounds scenarios per coalesced program; ``max_wait_s`` is
    the background worker's micro-batching window (how long the first
    request of a burst waits for companions); ``cache`` shares warm maps —
    a `MapCache`, a path (persistent, shareable with CLI sweeps), or None
    for a private in-memory pool.

    ``cost_table`` seeds the budget cost model with the engine's shared
    calibrated table (`engine.autotune.CostTable` or a path): classes with
    no executed batch yet fall back to the table's predicted
    per-scenario-iteration cost, so a request's FIRST batch can already be
    budget-enforced.  ``None`` (the default) keeps the legacy behavior —
    the first batch of each class calibrates, measured minima enforce from
    the second on — bit-identical results either way (`OnlineCost`).
    """

    def __init__(self, *, max_batch: int = 16, max_wait_s: float = 0.02,
                 cache: MapCache | str | None = None,
                 families: dict[str, ServedFamily] | None = None,
                 max_programs: int = 32,
                 cost_table: "autotune_mod.CostTable | str | None" = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.families = dict(SERVED_FAMILIES if families is None
                             else families)
        self.cache = (MapCache(cache) if isinstance(cache, str)
                      else (cache if cache is not None else MapCache()))
        self.metrics = ServeMetrics()
        self._cv = threading.Condition()
        self._pending: list[Ticket] = []
        self._stopping = False
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()        # programs + cost model
        self._programs: OrderedDict[tuple, Any] = OrderedDict()
        self._max_programs = max_programs
        if isinstance(cost_table, str):
            cost_table = autotune_mod.CostTable.load(cost_table)
        # The engine's shared cost model (§13): min-observed per-class
        # per-scenario-iteration seconds, with the table as prior.
        self._cost = autotune_mod.OnlineCost(table=cost_table)
        self._ids = iter(range(1 << 62))
        self._batch_ids = iter(range(1 << 62))

    # --- admission -----------------------------------------------------------

    def _resolve(self, request: IntegrationRequest):
        """Request -> (family, VegasConfig); raises PlanError on anything
        the service cannot serve (before make_plan sees it)."""
        spec = self.families.get(request.family)
        if spec is None:
            raise PlanError(
                f"unknown served family {request.family!r}; served: "
                f"{sorted(self.families)}")
        try:
            # Normalize INTO the request's dtype: a float64 param array
            # closed over by the family would otherwise promote every
            # sample/product in the fill to f64 behind the plan's back.
            params = spec.normalize(request.params, np.dtype(request.dtype))
        except Exception as e:
            raise PlanError(
                f"family {request.family!r} params not normalizable: "
                f"{e}") from None
        if params.shape[0] == 0:
            raise PlanError("request carries zero scenarios")
        if (request.time_budget_s is not None
                and not request.time_budget_s > 0):
            raise PlanError(
                f"time_budget_s must be positive, got "
                f"{request.time_budget_s}")
        try:
            family = spec.build(params, **dict(request.family_kwargs))
        except Exception as e:
            raise PlanError(
                f"family {request.family!r} rejected "
                f"kwargs={dict(request.family_kwargs)}: {e}") from None
        stop = (StopPolicy(rtol=request.rtol, atol=request.atol,
                           min_it=request.min_it)
                if (request.rtol != 0 or request.atol != 0) else None)
        precision = (PrecisionPolicy(accum_dtype=request.accum_dtype)
                     if request.accum_dtype else None)
        execution = ExecutionConfig(
            backend=request.backend, interpret=request.interpret,
            tile=request.tile, batch="vmap", stop=stop,
            precision=precision)
        cfg = core.VegasConfig(
            neval=request.neval, max_it=request.max_it, skip=request.skip,
            ninc=request.ninc, alpha=request.alpha, beta=request.beta,
            chunk=request.chunk, dtype=request.dtype, execution=execution)
        return family, params, cfg

    def submit(self, request: IntegrationRequest) -> Ticket:
        """Admit one request: plan-validate it (admission control — a
        `PlanError` here has touched no device) and enqueue it for the
        micro-batcher.  Returns the caller's :class:`Ticket`."""
        t = time.perf_counter()
        try:
            family, params, cfg = self._resolve(request)
            make_plan(family, cfg)     # the admission check (PlanError)
        except PlanError:
            self.metrics.record_reject()
            raise
        ticket = Ticket(request, next(self._ids), family, params, t)
        self.metrics.record_submit(t)
        with self._cv:
            self._pending.append(ticket)
            self._cv.notify_all()
        return ticket

    # --- the micro-batcher ---------------------------------------------------

    def _take_pending(self) -> list[Ticket]:
        with self._cv:
            pending, self._pending = self._pending, []
        return pending

    def _group(self, pending: list[Ticket]) -> list[list[Ticket]]:
        """FIFO greedy coalescing: same compat key, up to ``max_batch``
        scenarios per batch; a request is never split (one larger than
        max_batch forms its own batch)."""
        by_key: OrderedDict[tuple, list[Ticket]] = OrderedDict()
        for t in pending:
            by_key.setdefault(t.compat_key, []).append(t)
        batches = []
        for tickets in by_key.values():
            cur: list[Ticket] = []
            cur_n = 0
            for t in tickets:
                if cur and cur_n + t.n_scenarios > self.max_batch:
                    batches.append(cur)
                    cur, cur_n = [], 0
                cur.append(t)
                cur_n += t.n_scenarios
            if cur:
                batches.append(cur)
        return batches

    def drain(self) -> int:
        """Execute everything queued right now, in the calling thread.
        Returns the number of micro-batches run."""
        pending = self._take_pending()
        if not pending:
            return 0
        batches = self._group(pending)
        for tickets in batches:
            try:
                self._run_batch(tickets)
            except Exception as e:
                self.metrics.record_failed(len(tickets))
                for t in tickets:
                    t._fail(e)
        return len(batches)

    def _program(self, key: tuple, plan):
        """The per-class compiled-program cache (LRU).  One jitted callable
        per compatibility class serves every batch size (jit retraces per
        B, reuses per shape) — a burst pays trace+compile once."""
        with self._lock:
            prog = self._programs.get(key)
            if prog is not None:
                self._programs.move_to_end(key)
                return prog
        prog = executor_mod.make_family_program(plan, with_caps=True)
        with self._lock:
            self._programs[key] = prog
            self._programs.move_to_end(key)
            while len(self._programs) > self._max_programs:
                self._programs.popitem(last=False)
        return prog

    def _caps_for(self, tickets: list[Ticket], rcfg,
                  batch_scenarios: int) -> tuple[np.ndarray, bool]:
        """Per-scenario iteration caps from each request's time budget and
        the class's per-iteration cost — the min-observed measurement, or
        the shared `CostTable` prediction for a class with no executed
        batch yet (`OnlineCost.unit`).  Returns ``(caps (B,), enforced)`` —
        ``enforced`` False while the class is uncalibrated AND no table
        prior exists (first batch), in which case every cap is ``max_it``."""
        req0 = tickets[0].request
        max_it = rcfg.max_it
        with self._lock:
            unit = self._cost.unit(tickets[0].compat_key, rcfg=rcfg,
                                   backend=req0.backend,
                                   interpret=req0.interpret, tile=req0.tile)
        caps, enforced = [], unit is not None
        for t in tickets:
            budget = t.request.time_budget_s
            if budget is None or unit is None:
                cap = max_it
            else:
                # The whole batch shares one wall clock: an iteration of
                # the batch costs ~unit * B, and the request's budget must
                # cover the iterations IT runs.
                cap = int(budget / (unit * batch_scenarios))
                cap = max(1, min(max_it, cap))
            caps.extend([cap] * t.n_scenarios)
        return np.asarray(caps, np.int32), enforced

    def _run_batch(self, tickets: list[Ticket]) -> None:
        """Execute one coalesced micro-batch and bill its requests."""
        t_start = time.perf_counter()
        req0 = tickets[0].request
        params = np.concatenate([t.params for t in tickets], axis=0)
        family, _, cfg = self._resolve(
            dataclasses.replace(req0, params=params))
        plan = make_plan(family, cfg)
        rcfg = plan.cfg
        b = plan.batch_size

        # Every request keeps its own stream: scenario j of request r draws
        # from fold_in(PRNGKey(r.seed), j) — invariant to coalescing.
        keys = jnp.concatenate(
            [scenario_keys(jax.random.PRNGKey(t.request.seed),
                           t.n_scenarios) for t in tickets], axis=0)
        caps, enforced = self._caps_for(tickets, rcfg, b)

        # Warm start from the shared map pool (batch-size-independent).
        pool_key = _PoolKey(family.name)
        pooled = self.cache.get(pool_key, rcfg)
        warm = pooled is not None
        edges0 = (jnp.broadcast_to(pooled, (b,) + pooled.shape[1:])
                  if warm
                  else executor_mod.uniform_family_edges(family, rcfg, b))

        prog = self._program(tickets[0].compat_key, plan)
        states, mean, sdev, chi2_dof, n_used = prog(
            family.params, keys, edges0, jnp.asarray(caps))
        res = executor_mod.package_batch_result(
            states, mean, sdev, chi2_dof, n_used, warm_started=warm)
        t_done = time.perf_counter()
        run_s = t_done - t_start

        # Cost model update: wall / (trips * B) approximates the
        # per-scenario-iteration cost; `OnlineCost.observe` keeps the
        # MINIMUM observed so trace+compile-inflated samples (the
        # calibration batch) never poison the estimate upward.
        trips = max(int(res.n_it_used.max()), 1)
        with self._lock:
            self._cost.observe(tickets[0].compat_key, run_s / (trips * b))

        # Refresh the pool with the scenario-averaged converged map.
        self.cache.put(pool_key, rcfg,
                       np.asarray(res.states.edges).mean(axis=0,
                                                         keepdims=True))

        batch_id = next(self._batch_ids)
        self.metrics.record_batch(
            n_requests=len(tickets), n_scenarios=b, run_s=run_s,
            cache_hit=warm, t_done=t_done)
        self._bill(tickets, res, caps, enforced, rcfg, run_s, t_start,
                   batch_id, b)

    def _bill(self, tickets, res, caps, enforced, rcfg, run_s, t_start,
              batch_id, batch_size) -> None:
        lo = 0
        for t in tickets:
            hi = lo + t.n_scenarios
            mean = res.mean[lo:hi]
            sdev = res.sdev[lo:hi]
            n_it = res.n_it_used[lo:hi]
            cap = caps[lo:hi]
            req = t.request
            met = None
            if req.has_precision_target:
                target = np.maximum(req.rtol * np.abs(mean), req.atol)
                met = sdev <= target
            billed = int(n_it.sum())
            result = RequestResult(
                request_id=t.request_id, family=req.family, mean=mean,
                sdev=sdev, chi2_dof=res.chi2_dof[lo:hi],
                n_it_used=n_it.astype(np.int64),
                targets=(None if t.family.targets is None
                         else np.asarray(t.family.targets)),
                met_precision=met, it_cap=cap.astype(np.int64),
                capped=bool((n_it >= cap).any() and (cap < rcfg.max_it).any()),
                budget_enforced=(enforced
                                 and req.time_budget_s is not None),
                billed_iterations=billed,
                billed_evals=billed * req.neval,
                queue_s=t_start - t.t_submit, run_s=run_s,
                batch_id=batch_id, batch_size=batch_size,
                warm_started=res.warm_started)
            self.metrics.record_request_done(
                n_scenarios=t.n_scenarios, queue_s=result.queue_s,
                billed_iterations=billed,
                saved_iterations=t.n_scenarios * rcfg.max_it - billed,
                capped_scenarios=int(((n_it >= cap)
                                      & (cap < rcfg.max_it)).sum()))
            t._resolve(result)
            lo = hi

    # --- the long-lived worker -----------------------------------------------

    def start(self) -> "SweepService":
        """Start the background worker: gathers each burst for
        ``max_wait_s`` (the micro-batching window) and drains it."""
        with self._cv:
            if self._thread is not None:
                return self
            self._stopping = False
        self._thread = threading.Thread(target=self._worker,
                                        name="sweep-service", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain outstanding work and stop the worker."""
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.drain()   # anything submitted after the worker exited

    def _worker(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._stopping:
                    self._cv.wait()
                if not self._pending and self._stopping:
                    return
            if self.max_wait_s > 0:
                time.sleep(self.max_wait_s)   # let the burst arrive
            self.drain()

    def __enter__(self) -> "SweepService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --- observability -------------------------------------------------------

    def stats(self) -> dict:
        """The metrics endpoint: request/batch/cache/latency/billing
        aggregates (`ServeMetrics.snapshot`) plus the live cost model."""
        snap = self.metrics.snapshot()
        with self._lock:
            snap["cost_model"] = {
                "classes_calibrated": self._cost.classes_calibrated,
                "per_scenario_iteration_s": self._cost.snapshot(),
                "table": (None if self._cost.table is None
                          else self._cost.table.source),
            }
            snap["programs_cached"] = len(self._programs)
        return snap
