"""Service metrics: what `SweepService.stats()` reports (DESIGN.md §12).

One thread-safe accumulator object per service.  Counters are updated by
the admission path (submitted/rejected) and the micro-batcher
(batches/occupancy/cache/latency/billing); :meth:`ServeMetrics.snapshot`
renders the aggregate view the ``stats()`` endpoint and the load-generator
benchmark (`benchmarks/bench_serve.py`) consume.
"""

from __future__ import annotations

import threading


def _mean(xs) -> float:
    return float(sum(xs) / len(xs)) if xs else 0.0


class ServeMetrics:
    """Thread-safe counters + latency/occupancy series for one service."""

    def __init__(self):
        self._lock = threading.Lock()
        self.submitted = 0
        self.rejected = 0
        self.completed = 0
        self.failed = 0
        self.scenarios_completed = 0
        self.batches = 0
        self.occupancy: list[int] = []        # scenarios per batch
        self.coalesced: list[int] = []        # requests per batch
        self.cache_hits = 0
        self.cache_misses = 0
        self.queue_s: list[float] = []        # per request
        self.run_s: list[float] = []          # per batch
        self.billed_iterations = 0
        self.saved_iterations = 0             # vs every scenario running
                                              # max_it
        self.capped_scenarios = 0             # stopped by a time-budget cap
        self.first_submit_t: float | None = None
        self.last_done_t: float | None = None

    def record_submit(self, t: float) -> None:
        with self._lock:
            self.submitted += 1
            if self.first_submit_t is None:
                self.first_submit_t = t

    def record_reject(self) -> None:
        with self._lock:
            self.rejected += 1

    def record_batch(self, *, n_requests: int, n_scenarios: int,
                     run_s: float, cache_hit: bool, t_done: float) -> None:
        with self._lock:
            self.batches += 1
            self.coalesced.append(n_requests)
            self.occupancy.append(n_scenarios)
            self.run_s.append(run_s)
            if cache_hit:
                self.cache_hits += 1
            else:
                self.cache_misses += 1
            self.last_done_t = t_done

    def record_request_done(self, *, n_scenarios: int, queue_s: float,
                            billed_iterations: int, saved_iterations: int,
                            capped_scenarios: int) -> None:
        with self._lock:
            self.completed += 1
            self.scenarios_completed += n_scenarios
            self.queue_s.append(queue_s)
            self.billed_iterations += billed_iterations
            self.saved_iterations += saved_iterations
            self.capped_scenarios += capped_scenarios

    def record_failed(self, n: int = 1) -> None:
        with self._lock:
            self.failed += n

    def snapshot(self) -> dict:
        """The ``stats()`` payload: plain data, JSON-serializable."""
        with self._lock:
            lookups = self.cache_hits + self.cache_misses
            span = ((self.last_done_t - self.first_submit_t)
                    if self.first_submit_t is not None
                    and self.last_done_t is not None else 0.0)
            return {
                "requests": {
                    "submitted": self.submitted,
                    "rejected": self.rejected,
                    "completed": self.completed,
                    "failed": self.failed,
                    "in_flight": (self.submitted - self.completed
                                  - self.failed),
                    "scenarios_completed": self.scenarios_completed,
                },
                "batches": {
                    "count": self.batches,
                    "mean_occupancy": _mean(self.occupancy),
                    "max_occupancy": max(self.occupancy, default=0),
                    "mean_requests_coalesced": _mean(self.coalesced),
                },
                "cache": {
                    "hits": self.cache_hits,
                    "misses": self.cache_misses,
                    "hit_rate": (self.cache_hits / lookups if lookups
                                 else 0.0),
                },
                "latency_s": {
                    "queue_mean": _mean(self.queue_s),
                    "queue_max": max(self.queue_s, default=0.0),
                    "run_mean": _mean(self.run_s),
                    "run_max": max(self.run_s, default=0.0),
                },
                "iterations": {
                    "billed": self.billed_iterations,
                    "saved_vs_max_it": self.saved_iterations,
                    "capped_scenarios": self.capped_scenarios,
                },
                "throughput": {
                    "requests_per_s": (self.completed / span if span > 0
                                       else 0.0),
                    "wall_span_s": span,
                },
            }
