"""Serving loop: batched prefill + greedy/temperature decode.

serve_step is the unit the dry-run lowers for the decode_* shapes: one new
token against a fixed-size KV cache."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import transformer as T


def serve_step(params, cache, token, pos, cfg):
    """One decode step (the dry-run unit). token (b,), pos () -> logits, cache."""
    return T.decode_step(params, cache, token, pos, cfg)


def generate(params, prompt, cfg, *, steps: int, key=None, temperature=0.0,
             cache_len: int | None = None, memory=None):
    """Greedy (or sampled) generation driver used by the examples.

    prompt (b, s) int32. Returns tokens (b, steps).
    """
    b, s = prompt.shape
    cache_len = cache_len or (s + steps)
    last_logits, cache = T.prefill(params, prompt, cfg, cache_len=cache_len,
                                   memory=memory)
    step_fn = jax.jit(functools.partial(T.decode_step, cfg=cfg))

    toks = []
    logits = last_logits
    for i in range(steps):
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        toks.append(nxt)
        logits, cache = step_fn(params, cache, nxt.astype(jnp.int32),
                                jnp.array(s + i, jnp.int32))
    return jnp.stack(toks, axis=1)
