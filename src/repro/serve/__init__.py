"""repro.serve: integration-as-a-service on the unified engine (§12).

A long-lived :class:`SweepService` admits :class:`IntegrationRequest`s
through ``make_plan`` (invalid combinations rejected with `PlanError`
before touching a device), coalesces compatible queued requests into ONE
vmapped program with per-scenario stop masks and time-budget iteration
caps, warm-starts from a shared `MapCache`, and bills each request by its
own ``n_it_used``.

    from repro.serve import IntegrationRequest, SweepService

    with SweepService(max_batch=16) as svc:
        t = svc.submit(IntegrationRequest(
            family="gaussian", params=[0.3, 0.5], rtol=5e-3,
            time_budget_s=2.0, seed=7))
        print(t.result(timeout=60.0))
    print(svc.stats())
"""

from .metrics import ServeMetrics
from .request import IntegrationRequest, RequestResult, Ticket
from .service import SERVED_FAMILIES, ServedFamily, SweepService

__all__ = [
    "IntegrationRequest",
    "RequestResult",
    "Ticket",
    "ServeMetrics",
    "ServedFamily",
    "SERVED_FAMILIES",
    "SweepService",
]
