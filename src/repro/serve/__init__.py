"""Serving substrate: prefill + batched decode."""
