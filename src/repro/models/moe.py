"""Top-k Mixture-of-Experts with sort-based (capacity) dispatch.

FLOP-honest dispatch: instead of the Switch-style dense one-hot einsum (whose
dispatch FLOPs exceed the expert FLOPs at E=384), token->expert assignment is
materialized by sorting the (token, expert) pairs and gathering tokens into an
(E, C, D) grouped buffer; experts run as one grouped einsum; results scatter
back weighted by router probabilities.  Tokens beyond an expert's capacity
C = ceil(T*top_k/E * capacity_factor) are dropped (standard practice).

Sharding: expert dim E on the "model" axis (expert parallelism); the grouped
einsum is then fully local per device and XLA inserts the token all-to-alls.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .sharding import constrain


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_moe(key, cfg):
    d = cfg.d_model
    m = cfg.moe
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.params_dtype)
    s = 1.0 / math.sqrt(d)
    return {
        "router": _init(ks[0], (d, m.n_experts), s, jnp.float32),
        "w1": _init(ks[1], (m.n_experts, d, m.d_ff), s, dt),
        "w3": _init(ks[2], (m.n_experts, d, m.d_ff), s, dt),
        "w2": _init(ks[3], (m.n_experts, m.d_ff, d), s / math.sqrt(cfg.n_layers), dt),
    }


def moe_capacity(n_tokens: int, cfg) -> int:
    m = cfg.moe
    c = int(math.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor))
    return max(8, -(-c // 8) * 8)  # pad to a multiple of 8 lanes


def moe_apply(p, x, cfg):
    """x (b, s, d) -> (b, s, d). Aux losses omitted at this scale (router
    z-loss/load-balance hooks would attach here)."""
    b, s, d = x.shape
    m = cfg.moe
    ct = jnp.dtype(cfg.compute_dtype)
    t = b * s
    xt = constrain(x.reshape(t, d), "dp", None)

    logits = (xt.astype(jnp.float32) @ p["router"])           # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, m.top_k)              # (t, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch ------------------------------------------
    cap = moe_capacity(t, cfg)
    flat_e = expert.reshape(-1)                               # (t*k,)
    flat_tok = jnp.repeat(jnp.arange(t), m.top_k)
    flat_gate = gate.reshape(-1)
    order = jnp.argsort(flat_e)                               # stable
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    gate_sorted = flat_gate[order]
    # rank of each assignment within its expert
    counts = jnp.bincount(flat_e, length=m.n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * m.top_k) - starts[e_sorted]
    keep = rank < cap
    slot = jnp.where(keep, e_sorted * cap + rank, m.n_experts * cap)

    # gather tokens into the grouped buffer (E*C, d)
    buf_tok = jnp.zeros((m.n_experts * cap + 1,), jnp.int32).at[slot].set(
        tok_sorted.astype(jnp.int32))
    buf_live = jnp.zeros((m.n_experts * cap + 1,), ct).at[slot].set(
        keep.astype(ct))
    buf_tok, buf_live = buf_tok[:-1], buf_live[:-1]
    # Cast BEFORE the gather (an f32 gather doubles the dominant buffer) and
    # pin the expert-major flat layout to the EP axis (= data, matching the
    # expert-weight sharding so the grouped einsum is local).
    ep = "dp" if cfg.moe_ep_over_data else "tp"
    xtc = xt.astype(ct)
    xg = constrain(xtc[buf_tok], ep, None) * buf_live[:, None]   # (E*C, d)
    xg = constrain(xg.reshape(m.n_experts, cap, d), ep, None, None)

    # grouped expert FFN (einsum over the expert dim = expert parallelism)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, p["w1"].astype(ct))) \
        * jnp.einsum("ecd,edf->ecf", xg, p["w3"].astype(ct))
    yg = constrain(jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(ct)),
                   ep, None, None)                             # (E, C, d)

    # combine: scatter straight from the expert-major (E*C, d) layout — a
    # per-assignment (t*k, d) gather here would materialize an unsharded
    # buffer (observed 2x 4.3 GB f32/device on jamba-398b).  Each buffer
    # slot knows its token (buf_tok) and gate weight; empty slots carry 0.
    yflat = constrain(yg.reshape(m.n_experts * cap, d), ep, None)
    gate_slot = jnp.zeros((m.n_experts * cap + 1,), ct).at[slot].set(
        (gate_sorted * keep).astype(ct))[:-1]
    contrib = jnp.zeros((t, d), ct).at[buf_tok].add(
        yflat * gate_slot[:, None])
    contrib = constrain(contrib, "dp", None)
    return contrib.reshape(b, s, d).astype(x.dtype)
