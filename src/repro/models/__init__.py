"""LM substrate: composable decoder architectures for the assigned configs."""

from .config import ArchConfig, Block  # noqa: F401
