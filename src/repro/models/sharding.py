"""Sharding policy: Megatron-style TP on "model", DP over ("pod","data"),
optional FSDP (params + optimizer sharded over the data axis), EP for MoE
(experts on "model"), sequence-sharded KV for long-context decode.

Specs are derived from the parameter tree by path, so any block pattern the
config system can express gets a consistent policy.  Pods replicate params
(pure DP over DCN); FSDP shards within a pod only.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _tp_enabled(cfg) -> bool:
    # tiny models (smollm) don't tensor-parallelize: d_ff < 16 lanes/shard
    return cfg.d_model >= 1024


# --------------------------------------------------------------------------
# Mesh context: lets model code pin ACTIVATION shardings. Without these
# constraints GSPMD may resolve the FSDP(param-over-data) vs DP(batch-over-
# data) conflict by all-gathering activations — observed to blow per-device
# memory by the full DP factor (llama-vision train: 105 GB -> fits after).
# --------------------------------------------------------------------------

_CTX = {"mesh": None, "tp": "model", "dp": ("data",)}


def set_mesh_context(mesh, *, dp_axes=("data",), tp="model"):
    _CTX.update(mesh=mesh, dp=tuple(dp_axes), tp=tp)


def clear_mesh_context():
    _CTX.update(mesh=None)


def _ctx_axis_size(entry, mesh):
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def constrain(x, *entries):
    """with_sharding_constraint(x, P(*entries)) under the mesh context.
    No-op outside a context (CPU tests), when the mesh lacks the resolved
    axis (e.g. 'tp'->'model' on the 1D local data mesh), or when a dim
    doesn't divide.  Entries use the placeholders 'dp'/'tp' resolved from
    the context."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    resolved = []
    for i, e in enumerate(entries):
        if e == "dp":
            e = _CTX["dp"] if len(_CTX["dp"]) > 1 else _CTX["dp"][0]
        elif e == "tp":
            e = _CTX["tp"]
        if e is not None:
            axes = e if isinstance(e, tuple) else (e,)
            if any(a not in mesh.shape for a in axes):
                e = None
            elif x.shape[i] % _ctx_axis_size(e, mesh) != 0:
                e = None
        resolved.append(e)
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


def param_specs(cfg, *, tp="model", dp="data"):
    """PartitionSpec pytree matching transformer.init_params(cfg)."""
    from . import transformer as T  # deferred: transformer imports constrain
    shapes = jax.eval_shape(lambda k: T.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    use_tp = _tp_enabled(cfg)
    fs = dp if (cfg.fsdp and use_tp) else None

    def spec_for(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = names[-1]
        stacked = "period" in names           # leading n_periods axis
        lead = (None,) if stacked else ()
        if not use_tp:
            return P(*(lead + (None,) * (leaf.ndim - len(lead))))
        if name == "embed":
            return P(tp, fs)
        if name == "head":
            return P(fs, tp)
        if name in ("wq", "wk", "wv", "w1", "w3", "wz", "wx", "wdt"):
            return P(*lead, fs, tp)
        if name in ("wo", "w2") and leaf.ndim - len(lead) == 2:
            return P(*lead, tp, fs)
        if name == "router":
            return P(*lead, fs, None)
        # MoE experts: EP over the DATA axis + TP on d_ff. Sharding experts
        # over dp means weights never move — tokens all-to-all to their
        # expert's owner. (FSDP-sharding experts instead forces an all-gather
        # of ALL E experts per layer per micro while only top_k are used:
        # measured 28 TB/device/step of ICI traffic on kimi-1T, §Perf B1.)
        ep = dp if getattr(cfg, "moe_ep_over_data", True) else tp
        if name in ("w1", "w3") and leaf.ndim - len(lead) == 3:
            return (P(*lead, ep, None, tp) if ep == dp
                    else P(*lead, tp, fs, None))   # (E, D, F)
        if name == "w2" and leaf.ndim - len(lead) == 3:
            return (P(*lead, ep, tp, None) if ep == dp
                    else P(*lead, tp, fs, None))   # (E, F, D)
        if name in ("wb", "wc"):
            return P(*lead, fs, None)
        if name == "conv_x":
            return P(*lead, None, tp)
        if name in ("a_log", "d_skip", "dt_bias", "norm") and leaf.ndim - len(lead) == 1:
            return P(*lead, tp) if name != "norm" else P(*lead, tp)
        # norms ("scale"), everything else: replicated (modulo stacking)
        return P(*(lead + (None,) * (leaf.ndim - len(lead))))

    def fix_moe(path, leaf):
        # disambiguate mlp w1/w3/w2 (2D) from moe (3D) — handled by ndim above
        return spec_for(path, leaf)

    return jax.tree_util.tree_map_with_path(fix_moe, shapes)


def batch_spec(*, dp_axes):
    return P(dp_axes, None)


def cache_specs(cfg, kind: str, *, tp="model", dp_axes=("data",)):
    """Decode-cache PartitionSpecs. kind: 'decode' (batch >= dp) shards batch
    on data and kv-seq on model; 'long' (batch=1) shards kv-seq across the
    whole mesh (sequence parallelism for the 500k cache)."""
    use_tp = _tp_enabled(cfg)
    seq_axes_long = tuple(a for a in (*dp_axes, tp))
    specs = {}
    for j, blk in enumerate(cfg.blocks):
        if blk.mixer in ("attn", "swa"):
            if kind == "decode":
                s = P(None, dp_axes, tp if use_tp else None, None, None)
            else:
                s = P(None, None, seq_axes_long, None, None)
            specs[f"slot{j}"] = {"k": s, "v": s}
        elif blk.mixer == "xattn":
            s = (P(None, dp_axes, None, tp if use_tp else None, None)
                 if kind == "decode" else P(None, None, None, None, None))
            specs[f"slot{j}"] = {"mk": s, "mv": s}
        elif blk.mixer == "mamba":
            if kind == "decode":
                specs[f"slot{j}"] = {
                    "ssm": P(None, dp_axes, tp if use_tp else None, None, None),
                    "conv": P(None, dp_axes, None, tp if use_tp else None)}
            else:
                specs[f"slot{j}"] = {
                    "ssm": P(None, None, tp if use_tp else None, None, None),
                    "conv": P(None, None, None, tp if use_tp else None)}
    return specs


def sanitize_specs(specs, shapes, mesh):
    """Drop spec entries that don't divide the dimension evenly (NamedSharding
    refuses uneven tiling; e.g. vocab 50280 on a 16-way model axis, or kv=8
    heads on model=16).  Applied at lowering time when the mesh is known."""
    def ax_size(entry):
        if entry is None:
            return 1
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n

    def fix(spec, shape):
        dims = shape.shape
        ent = list(spec) + [None] * (len(dims) - len(spec))
        out = [e if (e is None or dims[i] % ax_size(e) == 0) else None
               for i, e in enumerate(ent)]
        return P(*out)

    return jax.tree.map(fix, specs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def opt_specs_adam(pspecs):
    return {"m": pspecs, "v": pspecs, "step": P()}


def _drop_axis(spec, axis):
    t = tuple(spec)
    return P(*(t[:axis] + t[axis + 1:]))


def opt_specs_adafactor(pspecs, pshapes):
    """Factored second moment: vr drops the last dim, vc the second-to-last
    (only for >=2D params; 1D keep full v)."""
    def f(spec, shape):
        if len(shape.shape) >= 2:
            return {"vr": _drop_axis(spec, len(shape.shape) - 1),
                    "vc": _drop_axis(spec, len(shape.shape) - 2)}
        return {"v": spec}
    return {"fac": jax.tree.map(f, pspecs, pshapes,
                                is_leaf=lambda x: isinstance(x, P)),
            "step": P()}
