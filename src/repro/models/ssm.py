"""Mamba2 (SSD — state-space duality) mixer.

Training/prefill uses the chunked SSD algorithm (intra-chunk dense matmuls +
inter-chunk state recurrence via lax.scan); decode is the O(1)-state
recurrent update.  Single kv-group (n_groups=1) as in mamba2-1.3b.

Projections are split per component (z, x, B, C, dt) instead of one fused
in_proj so each piece gets a clean tensor-parallel sharding (DESIGN.md §5).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_mamba(key, cfg):
    d = cfg.d_model
    s = cfg.ssm
    di, nh, ns = s.d_inner(d), s.n_heads(d), s.d_state
    dt = jnp.dtype(cfg.params_dtype)
    ks = jax.random.split(key, 8)
    sc = 1.0 / math.sqrt(d)
    return {
        "wz": _init(ks[0], (d, di), sc, dt),
        "wx": _init(ks[1], (d, di), sc, dt),
        "wb": _init(ks[2], (d, ns), sc, dt),
        "wc": _init(ks[3], (d, ns), sc, dt),
        "wdt": _init(ks[4], (d, nh), sc, dt),
        "conv_x": _init(ks[5], (s.conv_width, di), 0.5, dt),
        "a_log": jnp.zeros((nh,), dt),            # A = -exp(a_log) in (-inf,0)
        "d_skip": jnp.ones((nh,), dt),
        "dt_bias": jnp.zeros((nh,), dt),
        "wo": _init(ks[6], (di, d), sc / math.sqrt(cfg.n_layers), dt),
        "norm": jnp.ones((di,), dt),
    }


def _causal_conv(x, w):
    """Depthwise causal conv. x (b, l, c), w (k, c)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out


def _gated_norm(x, z, scale, eps=1e-5):
    g = x * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return g * inv * scale.astype(x.dtype)


def _ssd_chunked(xh, dt, a, b_in, c_in, chunk):
    """Chunked SSD scan.

    xh (b, l, h, p): inputs per head; dt (b, l, h) positive step sizes;
    a (h,) negative decay rates; b_in/c_in (b, l, n) single-group B/C.
    Returns y (b, l, h, p) and final state (b, h, p, n).
    """
    bsz, l, h, p = xh.shape
    n = b_in.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    q = chunk

    def r(t, shape):  # reshape into chunks
        return t.reshape((bsz, nc) + shape)

    xh_c = r(xh, (q, h, p))
    dt_c = r(dt, (q, h))
    b_c = r(b_in, (q, n))
    c_c = r(c_in, (q, n))

    dta = dt_c * a[None, None, None, :]                 # (b, nc, q, h)
    cum = jnp.cumsum(dta, axis=2)                       # within-chunk cumsum
    # intra-chunk: M[h,i,j] = scores[i,j] * exp(cum_i - cum_j) * dt_j, i >= j.
    # Built explicitly as (b,nc,h,q,q) and contracted with ONE dot: a naive
    # 4-operand einsum lets XLA materialize a 6D (b,nc,q,h,q,p) temp that is
    # 64x larger (observed 8.6 GB/device on jamba-398b train).
    cum_t = cum.transpose(0, 1, 3, 2)                   # (b,nc,h,q)
    li = cum_t[..., :, None] - cum_t[..., None, :]      # (b,nc,h,q,q)
    mask = jnp.tril(jnp.ones((q, q), bool))
    ldec = jnp.where(mask[None, None, None], jnp.exp(li), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", c_c, b_c)    # (b,nc,q,q)
    m_mat = (scores[:, :, None] * ldec
             * dt_c.transpose(0, 1, 3, 2)[:, :, :, None, :])  # (b,nc,h,q,q)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", m_mat, xh_c)

    # chunk-level states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
    decay_tail = jnp.exp(cum[:, :, -1:, :] - cum)       # (b,nc,q,h)
    s_chunk = jnp.einsum("bcjn,bcjh,bcjh,bcjhp->bchpn",
                         b_c, decay_tail, dt_c, xh_c)
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # (b,nc,h)

    def body(h_state, inp):
        s_c, dec = inp                                   # (b,h,p,n), (b,h)
        h_new = h_state * dec[:, :, None, None] + s_c
        return h_new, h_state                            # emit state BEFORE chunk

    h0 = jnp.zeros((bsz, h, p, n), xh.dtype)
    h_final, h_prev = jax.lax.scan(
        body, h0, (s_chunk.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)             # (b,nc,h,p,n)

    # inter-chunk: y_off_i = C_i . (exp(cum_i) * H_prev)
    y_off = jnp.einsum("bcin,bcih,bchpn->bcihp",
                       c_c, jnp.exp(cum), h_prev)
    y = (y_diag + y_off).reshape(bsz, l, h, p)
    return y, h_final


def mamba_train(p, x, cfg):
    """Full-sequence mixer. x (b, l, d) -> (b, l, d)."""
    s = cfg.ssm
    d = cfg.d_model
    di, nh, ns = s.d_inner(d), s.n_heads(d), s.d_state
    ct = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(ct)
    z = xc @ p["wz"].astype(ct)
    xi = xc @ p["wx"].astype(ct)
    b_in = xc @ p["wb"].astype(ct)
    c_in = xc @ p["wc"].astype(ct)
    dt = jax.nn.softplus((xc @ p["wdt"].astype(ct)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    xi = jax.nn.silu(_causal_conv(xi, p["conv_x"].astype(ct)))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    bsz, l = x.shape[:2]
    chunk = min(s.chunk, l)
    pad = (-l) % chunk
    if pad:
        xi = jnp.pad(xi, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    xh = xi.reshape(bsz, l + pad, nh, s.head_dim)
    y, _ = _ssd_chunked(xh, dt.astype(ct), a.astype(ct),
                        b_in, c_in, chunk)
    y = y[:, :l]
    y = y + xh[:, :l] * p["d_skip"].astype(ct)[None, None, :, None]
    y = y.reshape(bsz, l, di)
    y = _gated_norm(y, z, p["norm"])
    return (y.astype(ct) @ p["wo"].astype(ct)).astype(x.dtype)


def mamba_decode(p, x, state, cfg):
    """Single-token recurrent update. x (b, 1, d); state dict with
    'ssm' (b, h, p, n) and 'conv' (b, k-1, di). Returns (y, new_state)."""
    s = cfg.ssm
    d = cfg.d_model
    di, nh, ns = s.d_inner(d), s.n_heads(d), s.d_state
    ct = jnp.dtype(cfg.compute_dtype)
    xc = x[:, 0].astype(ct)                                   # (b, d)
    z = xc @ p["wz"].astype(ct)
    xi = xc @ p["wx"].astype(ct)
    b_in = xc @ p["wb"].astype(ct)                            # (b, n)
    c_in = xc @ p["wc"].astype(ct)
    dt = jax.nn.softplus((xc @ p["wdt"].astype(ct)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (b, h)

    # rolling conv buffer
    conv_buf = jnp.concatenate([state["conv"], xi[:, None, :]], axis=1)
    w = p["conv_x"].astype(ct)
    xi = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_buf.astype(ct), w))
    new_conv = conv_buf[:, 1:]

    a = -jnp.exp(p["a_log"].astype(jnp.float32))              # (h,)
    xh = xi.reshape(-1, nh, s.head_dim)                       # (b, h, p)
    dec = jnp.exp(dt * a[None, :]).astype(ct)                 # (b, h)
    dtc = dt.astype(ct)
    h_new = (state["ssm"] * dec[:, :, None, None]
             + jnp.einsum("bh,bhp,bn->bhpn", dtc, xh, b_in))
    y = jnp.einsum("bhpn,bn->bhp", h_new, c_in)
    y = y + xh * p["d_skip"].astype(ct)[None, :, None]
    y = y.reshape(-1, di)
    y = _gated_norm(y, z, p["norm"])
    out = (y.astype(ct) @ p["wo"].astype(ct)).astype(x.dtype)
    return out[:, None, :], {"ssm": h_new, "conv": new_conv}


def init_mamba_state(cfg, batch, dtype):
    s = cfg.ssm
    d = cfg.d_model
    return {
        "ssm": jnp.zeros((batch, s.n_heads(d), s.head_dim, s.d_state), dtype),
        "conv": jnp.zeros((batch, s.conv_width - 1, s.d_inner(d)), dtype),
    }
