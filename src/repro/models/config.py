"""Architecture configuration.

A model is a stack of ``n_layers`` blocks. Blocks repeat with a short
``period`` (1 for uniform stacks, 5 for llama-vision's cross-attn cadence,
8 for jamba's 1:7 mamba/attn interleave): the layer scan runs over
``n_layers // period`` steps, each applying the ``period`` distinct block
templates in order.  This keeps the compiled HLO small (one period body)
while representing heterogeneous stacks faithfully.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Mixer = Literal["attn", "swa", "xattn", "mamba"]
Ffn = Literal["mlp", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class Block:
    mixer: Mixer = "attn"
    ffn: Ffn = "mlp"


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SsmConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 256               # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int                   # query heads (0 for attn-free archs)
    n_kv_heads: int
    d_ff: int                      # dense-MLP hidden (0 if none)
    vocab: int
    blocks: tuple[Block, ...]      # one period of block templates
    head_dim: int | None = None
    moe: MoeConfig | None = None
    ssm: SsmConfig | None = None
    swa_window: int = 4096
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    # modality frontend stub: extra cross-attention memory (vlm only)
    xattn_memory_len: int = 0      # e.g. 576 image patch embeddings
    tie_embeddings: bool = False
    # large-scale training policy (see train/ and launch/dryrun.py)
    optimizer: str = "adamw"       # 'adamw' | 'adafactor'
    params_dtype: str = "float32"  # 'float32' | 'bfloat16' (>=1T configs)
    compute_dtype: str = "bfloat16"
    fsdp: bool = False             # shard params/opt over the data axis too
    microbatches_train_4k: int = 1  # grad-accumulation steps for train_4k
    sub_quadratic: bool = False    # eligible for long_500k decode
    dense_attn_threshold: int = 8192  # kv len above which attention is blocked
    remat_group: int = 1           # periods per 2-level-remat group (sqrt remat)
    moe_ep_over_data: bool = True  # experts sharded over data (EP) vs FSDP-style

    def __post_init__(self):
        assert self.n_layers % len(self.blocks) == 0, \
            (self.name, self.n_layers, len(self.blocks))
        if self.head_dim is None and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def period(self) -> int:
        return len(self.blocks)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND MODEL_FLOPS and memory sanity)."""
        d = self.d_model
        n = 0
        for blk in self.blocks:
            if blk.mixer in ("attn", "swa"):
                n += d * self.n_heads * self.head_dim      # wq
                n += 2 * d * self.n_kv_heads * self.head_dim  # wk, wv
                n += self.n_heads * self.head_dim * d      # wo
            elif blk.mixer == "xattn":
                n += d * self.n_heads * self.head_dim * 2  # wq, wo
                n += 2 * d * self.n_kv_heads * self.head_dim
            elif blk.mixer == "mamba":
                s = self.ssm
                di = s.d_inner(d)
                nh = s.n_heads(d)
                n += d * (2 * di + 2 * s.d_state + nh)     # in_proj (z,x,B,C,dt)
                n += s.conv_width * (di + 2 * s.d_state)   # convs
                n += di * d + 2 * nh + di                  # out_proj, A, D(dt_bias), norm
            if blk.ffn == "mlp":
                n += 3 * d * self.d_ff
            elif blk.ffn == "moe":
                n += d * self.moe.n_experts                # router
                n += self.moe.n_experts * 3 * d * self.moe.d_ff
            n += 2 * d                                     # 2 norms
        n *= self.n_periods
        n += self.vocab * d * (1 if self.tie_embeddings else 2)  # embed (+head)
        n += d                                             # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        moe_blocks = sum(1 for b in self.blocks if b.ffn == "moe") * self.n_periods
        dead = (self.moe.n_experts - self.moe.top_k) * 3 * self.d_model * self.moe.d_ff
        return full - moe_blocks * dead
