"""Core layers: RMSNorm, RoPE, GQA/SWA/cross attention (train + decode),
SwiGLU MLP.  Functional style: ``init_*`` builds a param dict, ``*_apply``
consumes it.  All matmuls run in ``cfg.compute_dtype``; norms/softmax in f32.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- norms ----

def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    # statistics in f32, application in x.dtype: avoids materializing a
    # full-size f32 copy of the residual stream (which XLA otherwise stacks
    # across the layer scan — 35 GB/device on mistral-123b train, §Perf A2).
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return x * inv * p["scale"].astype(x.dtype)


# ----------------------------------------------------------------- rope ----

def rope(x, positions, theta):
    """x (..., S, H, hd), positions (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----

def init_attention(key, cfg, cross=False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    dt = jnp.dtype(cfg.params_dtype)
    return {
        "wq": _init(ks[0], (d, hq * hd), s, dt),
        "wk": _init(ks[1], (d, hkv * hd), s, dt),
        "wv": _init(ks[2], (d, hkv * hd), s, dt),
        "wo": _init(ks[3], (hq * hd, d), s / math.sqrt(cfg.n_layers), dt),
    }


def _qkv(p, x, memory, cfg):
    """Project to q/k/v heads. memory!=None => cross-attention source."""
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ct = jnp.dtype(cfg.compute_dtype)
    src = x if memory is None else memory
    q = (x.astype(ct) @ p["wq"].astype(ct)).reshape(b, s, hq, hd)
    k = (src.astype(ct) @ p["wk"].astype(ct)).reshape(b, src.shape[1], hkv, hd)
    v = (src.astype(ct) @ p["wv"].astype(ct)).reshape(b, src.shape[1], hkv, hd)
    return q, k, v


def _expand_kv(k, hq):
    """GQA: repeat kv heads to match query heads."""
    hkv = k.shape[-2]
    if hkv == hq:
        return k
    return jnp.repeat(k, hq // hkv, axis=-2)


def _sdpa(q, k, v, mask, cfg):
    """Dense scaled-dot-product attention. q (b,sq,h,hd), k/v (b,sk,h,hd);
    mask (sq, sk) True=keep or None."""
    scale = 1.0 / math.sqrt(cfg.head_dim)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _blocked_causal_sdpa(q, k, v, cfg, window=None, block=1024):
    """Memory-bounded causal attention: scan over KV blocks with an online
    softmax (flash-attention dataflow in pure XLA).  Peak live memory is
    O(sq*block) per head instead of O(sq*sk).  For SWA only the blocks inside
    the window contribute (others are masked; the scan is still dense —
    the over-compute is visible in the roofline and addressed in §Perf)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    nb = -(-sk // block)
    pad = nb * block - sk
    scale = 1.0 / math.sqrt(hd)
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = kp.reshape(b, nb, block, h, hd).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(b, nb, block, h, hd).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry
        jblk, kj, vj = inp
        kpos = jblk * block + jnp.arange(block)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kj).astype(jnp.float32) * scale
        keep = kpos[None, :] <= qpos[:, None]
        if window is not None:
            keep &= kpos[None, :] > qpos[:, None] - window
        keep &= (kpos < sk)[None, :]
        logits = jnp.where(keep[None, None], logits, -1e30)
        mj = jnp.maximum(m, logits.max(-1))
        alpha = jnp.exp(m - mj)
        pj = jnp.exp(logits - mj[..., None])
        lj = l * alpha + pj.sum(-1)
        accj = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", pj.astype(q.dtype), vj).astype(jnp.float32)
        return (mj, lj, accj), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(nb), kb, vb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (b, sq, h, hd)


def attention_train(p, x, cfg, positions, *, window=None, memory=None,
                    dense_threshold=None):
    """Full-sequence attention (training / prefill)."""
    if dense_threshold is None:
        dense_threshold = cfg.dense_attn_threshold
    q, k, v = _qkv(p, x, memory, cfg)
    if memory is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    k = _expand_kv(k, cfg.n_heads)
    v = _expand_kv(v, cfg.n_heads)
    sq, sk = q.shape[1], k.shape[1]
    if memory is not None:
        out = _sdpa(q, k, v, None, cfg)             # cross-attn: no mask
    elif sk <= dense_threshold:
        mask = jnp.tril(jnp.ones((sq, sk), bool))
        if window is not None:
            mask &= jnp.triu(jnp.ones((sq, sk), bool), -window + 1)
        out = _sdpa(q, k, v, mask, cfg)
    else:
        out = _blocked_causal_sdpa(q, k, v, cfg, window=window)
    b, s = x.shape[:2]
    ct = jnp.dtype(cfg.compute_dtype)
    return (out.reshape(b, s, -1).astype(ct) @ p["wo"].astype(ct)).astype(x.dtype)


def attention_decode(p, x, cache_k, cache_v, pos, cfg, *, window=None,
                     memory_kv=None):
    """Single-token decode. x (b, 1, d); cache (b, S, hkv, hd); pos scalar.

    For SWA the cache is a rolling buffer of ``window`` positions; for cross
    attention the (precomputed) memory kv is attended instead of the cache.
    Returns (out, new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ct = jnp.dtype(cfg.compute_dtype)
    if memory_kv is not None:
        k, v = memory_kv
        q = (x.astype(ct) @ p["wq"].astype(ct)).reshape(b, 1, hq, hd)
        out = _sdpa(q, _expand_kv(k, hq), _expand_kv(v, hq), None, cfg)
        out = (out.reshape(b, 1, -1).astype(ct) @ p["wo"].astype(ct))
        return out.astype(x.dtype), cache_k, cache_v

    q = (x.astype(ct) @ p["wq"].astype(ct)).reshape(b, 1, hq, hd)
    k = (x.astype(ct) @ p["wk"].astype(ct)).reshape(b, 1, hkv, hd)
    v = (x.astype(ct) @ p["wv"].astype(ct)).reshape(b, 1, hkv, hd)
    q = rope(q, pos[None, None] if pos.ndim == 0 else pos[:, None], cfg.rope_theta)
    k = rope(k, pos[None, None] if pos.ndim == 0 else pos[:, None], cfg.rope_theta)

    s_cache = cache_k.shape[1]
    slot = pos % s_cache if window is not None else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), slot, axis=1)

    kk = _expand_kv(cache_k.astype(ct), hq)
    vv = _expand_kv(cache_v.astype(ct), hq)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
    kpos = jnp.arange(s_cache)
    if window is None:
        live = kpos <= pos                       # plain causal over the cache
    else:
        # rolling buffer: every written slot is inside the window already
        live = kpos < jnp.minimum(pos + 1, s_cache)
    logits = jnp.where(live[None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(ct)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    out = (out.reshape(b, 1, -1) @ p["wo"].astype(ct))
    return out.astype(x.dtype), cache_k, cache_v


# ------------------------------------------------------------------ mlp ----

def init_mlp(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.params_dtype)
    s = 1.0 / math.sqrt(d)
    return {"w1": _init(ks[0], (d, f), s, dt),
            "w3": _init(ks[1], (d, f), s, dt),
            "w2": _init(ks[2], (f, d), s / math.sqrt(cfg.n_layers), dt)}


def mlp(p, x, cfg):
    ct = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(ct)
    h = jax.nn.silu(xc @ p["w1"].astype(ct)) * (xc @ p["w3"].astype(ct))
    return (h @ p["w2"].astype(ct)).astype(x.dtype)


# ------------------------------------------------------------ embeddings ----

def init_embedding(key, cfg):
    dt = jnp.dtype(cfg.params_dtype)
    ks = jax.random.split(key, 2)
    p = {"embed": _init(ks[0], (cfg.vocab, cfg.d_model), 1.0, dt)}
    if not cfg.tie_embeddings:
        p["head"] = _init(ks[1], (cfg.d_model, cfg.vocab),
                          1.0 / math.sqrt(cfg.d_model), dt)
    return p


def embed(p, tokens, cfg):
    return jnp.take(p["embed"], tokens, axis=0).astype(jnp.dtype(cfg.compute_dtype))


def unembed(p, x, cfg):
    ct = jnp.dtype(cfg.compute_dtype)
    w = p["head"] if "head" in p else p["embed"].T
    return x.astype(ct) @ w.astype(ct)
