"""Composable decoder: block-pattern model built from layers/ssm/moe.

The layer stack is executed as ``lax.scan`` over ``n_periods`` steps, each
step applying one *period* of block templates (config.py).  Period params are
stacked on a leading axis, which keeps the lowered HLO size independent of
depth — essential for compiling 61-88 layer configs against a 512-device
mesh — and gives remat a natural per-period boundary.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as MOE
from . import ssm as SSM
from .config import ArchConfig, Block
from .sharding import constrain


# ------------------------------------------------------------------ init ----

def _init_block(key, blk: Block, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    p = {"norm1": L.init_rmsnorm(cfg.d_model, jnp.dtype(cfg.params_dtype))}
    if blk.mixer in ("attn", "swa"):
        p["mixer"] = L.init_attention(ks[0], cfg)
    elif blk.mixer == "xattn":
        p["mixer"] = L.init_attention(ks[0], cfg, cross=True)
    elif blk.mixer == "mamba":
        p["mixer"] = SSM.init_mamba(ks[0], cfg)
    if blk.ffn != "none":
        p["norm2"] = L.init_rmsnorm(cfg.d_model, jnp.dtype(cfg.params_dtype))
        p["ffn"] = (L.init_mlp(ks[1], cfg) if blk.ffn == "mlp"
                    else MOE.init_moe(ks[1], cfg))
    return p


def init_params(key, cfg: ArchConfig):
    k_emb, k_layers = jax.random.split(key)
    period_keys = jax.random.split(k_layers, cfg.n_periods)

    def init_period(k):
        ks = jax.random.split(k, cfg.period)
        return {f"slot{j}": _init_block(ks[j], blk, cfg)
                for j, blk in enumerate(cfg.blocks)}

    return {
        "embedding": L.init_embedding(k_emb, cfg),
        "period": jax.vmap(init_period)(period_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model, jnp.dtype(cfg.params_dtype)),
    }


# --------------------------------------------------------------- forward ----

def _apply_block(p, x, blk: Block, cfg, positions, memory):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if blk.mixer == "attn":
        h = L.attention_train(p["mixer"], h, cfg, positions)
    elif blk.mixer == "swa":
        h = L.attention_train(p["mixer"], h, cfg, positions,
                              window=cfg.swa_window)
    elif blk.mixer == "xattn":
        h = L.attention_train(p["mixer"], h, cfg, positions, memory=memory)
    elif blk.mixer == "mamba":
        h = SSM.mamba_train(p["mixer"], h, cfg)
    x = x + h
    if blk.ffn != "none":
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        h = L.mlp(p["ffn"], h, cfg) if blk.ffn == "mlp" \
            else MOE.moe_apply(p["ffn"], h, cfg)
        x = x + h
    return x


def forward(params, tokens, cfg: ArchConfig, *, memory=None, remat=True):
    """Training forward: tokens (b, s) [+ memory (b, m, d) for vlm] ->
    logits (b, s, vocab).

    ``cfg.remat_group > 1`` enables two-level (sqrt) remat: the outer scan
    checkpoints only every ``remat_group``-th period boundary, so the saved
    residual stack shrinks by the group factor at the cost of one extra
    group forward during backprop (§Perf A3).
    """
    x = constrain(L.embed(params["embedding"], tokens, cfg), "dp", None, None)
    positions = jnp.arange(tokens.shape[1])

    ct = jnp.dtype(cfg.compute_dtype)

    def period_body(x, pp):
        # Cast the (still-sharded) param slices to compute dtype FIRST so the
        # FSDP all-gather moves bf16, not f32 — halves weight-gather HBM and
        # ICI traffic (§Perf A6).
        pp = jax.tree.map(
            lambda w: w.astype(ct) if (w.dtype == jnp.float32 and w.ndim >= 2)
            else w, pp)
        for j, blk in enumerate(cfg.blocks):
            x = _apply_block(pp[f"slot{j}"], x, blk, cfg, positions, memory)
        return constrain(x, "dp", None, None), None

    g = cfg.remat_group if remat else 1
    if g <= 1:
        body = jax.checkpoint(period_body) if remat else period_body
        x, _ = jax.lax.scan(body, x, params["period"])
    else:
        assert cfg.n_periods % g == 0, (cfg.n_periods, g)
        grouped = jax.tree.map(
            lambda t: t.reshape((cfg.n_periods // g, g) + t.shape[1:]),
            params["period"])

        @jax.checkpoint
        def group_body(x, pg):
            x, _ = jax.lax.scan(jax.checkpoint(period_body), x, pg)
            return x, None

        x, _ = jax.lax.scan(group_body, x, grouped)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params["embedding"], x, cfg)


# ----------------------------------------------------------------- cache ----

def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    """Decode cache pytree, leaves stacked over n_periods (axis 0)."""
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    np_ = cfg.n_periods
    cache = {}
    for j, blk in enumerate(cfg.blocks):
        if blk.mixer in ("attn", "swa"):
            s = min(cache_len, cfg.swa_window) if blk.mixer == "swa" else cache_len
            cache[f"slot{j}"] = {
                "k": jnp.zeros((np_, batch, s, hkv, hd), dtype),
                "v": jnp.zeros((np_, batch, s, hkv, hd), dtype)}
        elif blk.mixer == "xattn":
            m = cfg.xattn_memory_len
            cache[f"slot{j}"] = {
                "mk": jnp.zeros((np_, batch, m, hkv, hd), dtype),
                "mv": jnp.zeros((np_, batch, m, hkv, hd), dtype)}
        elif blk.mixer == "mamba":
            st = SSM.init_mamba_state(cfg, batch, dtype)
            cache[f"slot{j}"] = jax.tree.map(
                lambda t: jnp.broadcast_to(t[None], (np_,) + t.shape), st)
    return cache


# ---------------------------------------------------------------- decode ----

def _decode_block(p, x, blk: Block, cache_j, pos, cfg):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if blk.mixer in ("attn", "swa"):
        window = cfg.swa_window if blk.mixer == "swa" else None
        h, ck, cv = L.attention_decode(p["mixer"], h, cache_j["k"],
                                       cache_j["v"], pos, cfg, window=window)
        cache_j = {"k": ck, "v": cv}
    elif blk.mixer == "xattn":
        h, _, _ = L.attention_decode(p["mixer"], h, None, None, pos, cfg,
                                     memory_kv=(cache_j["mk"], cache_j["mv"]))
    elif blk.mixer == "mamba":
        h, cache_j = SSM.mamba_decode(p["mixer"], h, cache_j, cfg)
    x = x + h
    if blk.ffn != "none":
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        h = L.mlp(p["ffn"], h, cfg) if blk.ffn == "mlp" \
            else MOE.moe_apply(p["ffn"], h, cfg)
        x = x + h
    return x, cache_j


def decode_step(params, cache, token, pos, cfg: ArchConfig):
    """One decode step: token (b,) int32, pos () int32 ->
    (logits (b, vocab), new_cache)."""
    x = L.embed(params["embedding"], token[:, None], cfg)

    def body(x, inp):
        pp, cj = inp
        new = {}
        for j, blk in enumerate(cfg.blocks):
            x, new[f"slot{j}"] = _decode_block(pp[f"slot{j}"], x, blk,
                                               cj[f"slot{j}"], pos, cfg)
        return constrain(x, "dp", None, None), new

    x, new_cache = jax.lax.scan(body, x, (params["period"], cache))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embedding"], x, cfg)
    return logits[:, 0], new_cache


# --------------------------------------------------------------- prefill ----

def _prefill_block(p, x, blk: Block, cfg, positions, memory, cache_len,
                   cache_dtype):
    """Apply one block over the full prompt and emit its decode-cache entry."""
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    entry = None
    if blk.mixer in ("attn", "swa"):
        window = cfg.swa_window if blk.mixer == "swa" else None
        q, k, v = L._qkv(p["mixer"], h, None, cfg)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        kx = L._expand_kv(k, cfg.n_heads)
        vx = L._expand_kv(v, cfg.n_heads)
        s = x.shape[1]
        if s <= cfg.dense_attn_threshold:
            mask = jnp.tril(jnp.ones((s, s), bool))
            if window is not None:
                mask &= jnp.triu(jnp.ones((s, s), bool), -window + 1)
            out = L._sdpa(q, kx, vx, mask, cfg)
        else:
            out = L._blocked_causal_sdpa(q, kx, vx, cfg, window=window)
        ct = jnp.dtype(cfg.compute_dtype)
        h = (out.reshape(x.shape[0], s, -1).astype(ct)
             @ p["mixer"]["wo"].astype(ct)).astype(x.dtype)
        # cache slot i == token position i (swa: i % window, valid while the
        # prompt fits the window — the serving wrapper enforces this)
        keep = min(cache_len, cfg.swa_window) if blk.mixer == "swa" else cache_len
        kk = k[:, -keep:] if s > keep else k
        vv = v[:, -keep:] if s > keep else v
        if kk.shape[1] < keep:  # pad tail slots (masked out by pos in decode)
            pad = keep - kk.shape[1]
            kk = jnp.pad(kk, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vv = jnp.pad(vv, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if blk.mixer == "swa" and s > keep:
            # rolling buffer: position p lives at slot p % window
            shift = s % keep
            kk = jnp.roll(kk, shift, axis=1)
            vv = jnp.roll(vv, shift, axis=1)
        entry = {"k": kk.astype(cache_dtype), "v": vv.astype(cache_dtype)}
    elif blk.mixer == "xattn":
        h = L.attention_train(p["mixer"], h, cfg, positions, memory=memory)
        _, mk, mv = L._qkv(p["mixer"], h, memory, cfg)
        entry = {"mk": mk.astype(cache_dtype), "mv": mv.astype(cache_dtype)}
    elif blk.mixer == "mamba":
        # rerun the chunked scan, capturing the final state
        h, entry = _mamba_prefill(p["mixer"], h, cfg, cache_dtype)
    x = x + h
    if blk.ffn != "none":
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        h = L.mlp(p["ffn"], h, cfg) if blk.ffn == "mlp" \
            else MOE.moe_apply(p["ffn"], h, cfg)
        x = x + h
    return x, entry


def _mamba_prefill(p, x, cfg, cache_dtype):
    """Like ssm.mamba_train but also returns the decode state."""
    s = cfg.ssm
    d = cfg.d_model
    di, nh = s.d_inner(d), s.n_heads(d)
    ct = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(ct)
    z = xc @ p["wz"].astype(ct)
    xi = xc @ p["wx"].astype(ct)
    b_in = xc @ p["wb"].astype(ct)
    c_in = xc @ p["wc"].astype(ct)
    dt = jax.nn.softplus((xc @ p["wdt"].astype(ct)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    conv_tail = xi[:, -(s.conv_width - 1):, :]
    xi = jax.nn.silu(SSM._causal_conv(xi, p["conv_x"].astype(ct)))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    bsz, l = x.shape[:2]
    chunk = min(s.chunk, l)
    pad = (-l) % chunk
    if pad:
        xi = jnp.pad(xi, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    xh = xi.reshape(bsz, l + pad, nh, s.head_dim)
    y, h_final = SSM._ssd_chunked(xh, dt.astype(ct), a.astype(ct), b_in, c_in, chunk)
    y = y[:, :l] + xh[:, :l] * p["d_skip"].astype(ct)[None, None, :, None]
    y = y.reshape(bsz, l, di)
    y = SSM._gated_norm(y, z, p["norm"])
    out = (y.astype(ct) @ p["wo"].astype(ct)).astype(x.dtype)
    state = {"ssm": h_final.astype(cache_dtype),
             "conv": conv_tail.astype(cache_dtype)}
    return out, state


def prefill(params, tokens, cfg: ArchConfig, *, cache_len: int | None = None,
            memory=None, remat=True, cache_dtype=jnp.bfloat16):
    """Prompt processing: returns (last-token logits (b, vocab), cache).

    NOTE (padding caveat): prompts shorter than the cache are assumed
    right-aligned; serving-grade left-pad handling lives in serve/decode.py.
    """
    cache_len = cache_len or tokens.shape[1]
    x = L.embed(params["embedding"], tokens, cfg)
    positions = jnp.arange(tokens.shape[1])

    def period_body(x, pp):
        entries = {}
        for j, blk in enumerate(cfg.blocks):
            x, entries[f"slot{j}"] = _prefill_block(
                pp[f"slot{j}"], x, blk, cfg, positions, memory, cache_len,
                cache_dtype)
        return constrain(x, "dp", None, None), entries

    body = jax.checkpoint(period_body) if remat else period_body
    x, cache = jax.lax.scan(body, x, params["period"])
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = L.unembed(params["embedding"], x, cfg)
    return logits[:, 0], cache
