"""Closed-form validation targets for the application integrands."""

from __future__ import annotations

import math


def _norm_cdf(x: float) -> float:
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


def asian_geometric_closed_form(s0: float, strike: float, r: float,
                                sigma: float, t_mat: float, n: int) -> float:
    """Exact price of a discretely-monitored geometric-average Asian call.

    G = s0 * exp(mean_k log S_k) is lognormal with
      mu_G  = log s0 + (r - sigma^2/2) * dt * (n+1)/2
      var_G = sigma^2 * dt * (n+1)(2n+1)/(6n)
    where dt = T/n; then price = e^{-rT} (e^{mu+var/2} N(d1) - K N(d2)).
    """
    dt = t_mat / n
    mu = math.log(s0) + (r - 0.5 * sigma**2) * dt * (n + 1) / 2.0
    var = sigma**2 * dt * (n + 1) * (2 * n + 1) / (6.0 * n)
    sd = math.sqrt(var)
    d1 = (mu - math.log(strike) + var) / sd
    d2 = d1 - sd
    fwd = math.exp(mu + 0.5 * var)
    return math.exp(-r * t_mat) * (fwd * _norm_cdf(d1) - strike * _norm_cdf(d2))


def harmonic_propagator_exact(x: float, t_total: float) -> float:
    """Continuum <x|e^{-HT}|x> for the 1D harmonic oscillator (m=w=1):
    sqrt(1/(2 pi sinh T)) exp(-x^2 tanh(T/2)). Reference only — the lattice
    integral converges to this as N -> inf."""
    return math.sqrt(1.0 / (2.0 * math.pi * math.sinh(t_total))) * \
        math.exp(-x * x * math.tanh(t_total / 2.0))
