"""Adaptive importance-sampling map (the "VEGAS map", Lepage 1978/2021).

The map is a per-dimension piecewise-linear change of variables
``y in [0,1) -> x in [a,b]`` defined by ``ninc`` intervals whose widths adapt
so that each interval contributes equally to ``int |J f|^2``.  cuVegas keeps
the map on-GPU and updates it with a sequential walk (its "updateMap",
Alg. 1); here the update is re-expressed as cumsum + searchsorted + gather,
which is fully parallel on TPU (DESIGN.md C4).

All functions are pure and jit-safe; the map itself is a plain ``(d, ninc+1)``
array of interval edges.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Floor for damped weights: keeps every interval at non-zero width so the
# Jacobian never degenerates (vegas' TINY).
_TINY = 1e-30


def uniform_edges(lower, upper, ninc: int, dtype=jnp.float32) -> jax.Array:
    """Initial map: ``ninc`` equal intervals per dimension.

    lower/upper: (d,) integration bounds. Returns edges (d, ninc+1).
    """
    lower = jnp.asarray(lower, dtype)
    upper = jnp.asarray(upper, dtype)
    t = jnp.linspace(0.0, 1.0, ninc + 1, dtype=dtype)
    return lower[:, None] + (upper - lower)[:, None] * t[None, :]


def apply_map(edges: jax.Array, y: jax.Array):
    """Map uniform points ``y (n, d) in [0,1)`` through the grid.

    Returns ``(x, jac, iy)``:
      x   (n, d) points in the integration volume,
      jac (n,)   product over dims of ``ninc * dx_i`` (eq. (3) of the paper),
      iy  (n, d) int32 interval index per dimension (for weight accumulation).
    """
    ninc = edges.shape[1] - 1
    yn = y * ninc
    iy = jnp.clip(yn.astype(jnp.int32), 0, ninc - 1)
    frac = yn - iy
    # Single-index-array formulation: gather the left edge and the interval
    # width with the SAME indices (one fewer gather; also what the Pallas
    # kernel implements).
    widths = jnp.diff(edges, axis=1)                                  # (d, ninc)
    e_lo = jnp.take_along_axis(edges.T, iy, axis=0, mode="clip")     # (n, d)
    dx = jnp.take_along_axis(widths.T, iy, axis=0, mode="clip")      # (n, d)
    x = e_lo + frac * dx
    # Jacobian in log form. Two reasons: (a) prod(ninc*dx) overflows f32 for
    # strongly adapted high-d maps while the log-sum never does; (b) the
    # gather+reduce-prod fusion miscompiles on XLA:CPU (jax 0.8.2): jit
    # programs containing it produce all-NaN jac while the de-optimized
    # op-by-op execution is clean (confirmed via jax_debug_nans; see
    # DESIGN.md D4 note). The log form sidesteps the bad fusion cluster.
    jac = jnp.exp(jnp.sum(jnp.log(jnp.maximum(ninc * dx, _TINY)), axis=-1))
    return x, jac, iy


def accumulate_map_weights(iy: jax.Array, w2: jax.Array, cnt: jax.Array, ninc: int):
    """Reference accumulation of ``sum (J f)^2`` per (dim, interval).

    iy (n, d) int32, w2 (n,) weights, cnt (n,) 1.0 for live evals / 0.0 for
    masked tail. Returns (sums (d, ninc), counts (d, ninc)). The Pallas kernel
    computes the same contraction as one-hot matmuls on the MXU; this
    scatter-add form is the oracle.
    """
    d = iy.shape[1]
    flat = (jnp.arange(d, dtype=jnp.int32)[None, :] * ninc + iy).reshape(-1)
    sums = jnp.zeros((d * ninc,), w2.dtype).at[flat].add(
        jnp.repeat(w2[:, None], d, axis=1).reshape(-1))
    cnts = jnp.zeros((d * ninc,), w2.dtype).at[flat].add(
        jnp.repeat(cnt[:, None], d, axis=1).reshape(-1))
    return sums.reshape(d, ninc), cnts.reshape(d, ninc)


def _smooth_and_damp(sums: jax.Array, counts: jax.Array, alpha) -> jax.Array:
    """vegas' smoothing + alpha-damping of the accumulated weights.

    sums/counts: (d, ninc). Returns damped weights (d, ninc), >= _TINY.
    """
    avg = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), 0.0)
    # 3-point smoothing with (1,6,1)/8 interior and (7,1)/8 at the ends.
    left = jnp.concatenate([avg[:, :1], avg[:, :-1]], axis=1)
    right = jnp.concatenate([avg[:, 1:], avg[:, -1:]], axis=1)
    sm = (left + 6.0 * avg + right) / 8.0
    total = jnp.sum(sm, axis=1, keepdims=True)
    r = jnp.where(total > 0, sm / jnp.maximum(total, _TINY), 1.0 / sm.shape[1])
    # Damping: w = ((r - 1)/ln r)^alpha, the classic VEGAS compression. r is a
    # normalized distribution so r in [0, 1]; guard the r->0 and r->1 limits.
    r = jnp.clip(r, _TINY, 1.0 - 1e-12)
    w = ((r - 1.0) / jnp.log(r)) ** alpha
    return jnp.maximum(w, _TINY)


def adapt_edges(edges: jax.Array, sums: jax.Array, counts: jax.Array, alpha) -> jax.Array:
    """One map adaptation step (vectorized "updateMap").

    New edges are placed so every new interval holds an equal share of the
    damped weight; realized as piecewise-linear inversion of the cumulative
    weight via searchsorted (parallel; cuVegas does a sequential walk).
    """
    ninc = edges.shape[1] - 1
    w = _smooth_and_damp(sums, counts, alpha)          # (d, ninc)

    def per_dim(edges_d, w_d):
        cum = jnp.concatenate([jnp.zeros((1,), w_d.dtype), jnp.cumsum(w_d)])
        targets = cum[-1] * jnp.arange(1, ninc, dtype=w_d.dtype) / ninc
        j = jnp.clip(jnp.searchsorted(cum, targets, side="right") - 1, 0, ninc - 1)
        frac = (targets - cum[j]) / jnp.maximum(w_d[j], _TINY)
        new_mid = edges_d[j] + frac * (edges_d[j + 1] - edges_d[j])
        new = jnp.concatenate([edges_d[:1], new_mid, edges_d[-1:]])
        # Guard monotonicity against fp round-off in the interpolation.
        return jax.lax.cummax(new, axis=0)

    return jax.vmap(per_dim)(edges, w)
