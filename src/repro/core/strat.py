"""Adaptive stratified sampling (the "+" in VEGAS+).

y-space [0,1)^d is cut into ``nstrat`` equal slices per dimension (a grid of
``nstrat**d`` hypercubes).  Each cube h receives ``n_h`` integrand evaluations,
re-allocated every iteration proportionally to ``d_h**beta`` where d_h is the
cube's variance contribution (paper eq. (5)-(7)).

Shapes must stay static under jit, so the eval axis has a fixed capacity
``n_cap`` and iterations that need fewer evals mask the tail (DESIGN.md C2):
``mapEvalsToCubes`` is a searchsorted over ``cumsum(n_h)`` and out-of-range
evals get cube id ``n_cubes`` (an overflow bucket that is dropped).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def choose_nstrat(neval: int, dim: int, max_cubes: int = 1 << 20) -> int:
    """vegas' heuristic: ~(neval/2)^(1/dim) slices/dim, capped by max_cubes."""
    ns = int(math.floor((neval / 2.0) ** (1.0 / dim)))
    ns = max(ns, 1)
    while ns > 1 and ns**dim > max_cubes:
        ns -= 1
    return ns


def eval_capacity(neval: int, n_cubes: int) -> int:
    """Static eval-axis capacity: every cube is guaranteed >= 2 evals, so the
    adapted totals can exceed neval by at most 2 per cube."""
    return neval + 2 * n_cubes


def uniform_nh(neval: int, n_cubes: int) -> jax.Array:
    """Classic-VEGAS / m-CUBES allocation: equal evals per cube (beta = 0)."""
    base = max(neval // n_cubes, 2)
    return jnp.full((n_cubes,), base, dtype=jnp.int32)


def map_evals_to_cubes(n_h: jax.Array, n_cap: int):
    """cuVegas' mapEvalsToCubes, vectorized.

    Returns ``(cube (n_cap,) int32, n_used scalar)``. Evals past the active
    total get cube id ``n_cubes`` (overflow bucket).
    """
    cum = jnp.cumsum(n_h)
    e = jnp.arange(n_cap, dtype=cum.dtype)
    cube = jnp.searchsorted(cum, e, side="right").astype(jnp.int32)
    return cube, cum[-1]


def cubes_for_slice(n_h: jax.Array, start, length: int):
    """Cube ids for a contiguous slice [start, start+length) of the *global*
    eval axis. ``start`` may be traced (shard-local offsets under shard_map);
    evals past the active total get the overflow id ``n_cubes``."""
    cum = jnp.cumsum(n_h)
    e = start + jnp.arange(length, dtype=cum.dtype)
    return jnp.searchsorted(cum, e, side="right").astype(jnp.int32)


def cube_coords(cube: jax.Array, nstrat: int, dim: int) -> jax.Array:
    """Decode cube ids (n,) into per-dimension stratification coords (n, dim)."""
    pows = nstrat ** jnp.arange(dim, dtype=jnp.int64 if nstrat**dim > 2**31 else jnp.int32)
    return (cube[:, None] // pows[None, :]) % nstrat


def stratified_y(cube: jax.Array, u: jax.Array, nstrat: int) -> jax.Array:
    """Uniform u (n, d) -> stratified y (n, d): offset into the cube's cell."""
    coords = cube_coords(cube, nstrat, u.shape[1]).astype(u.dtype)
    return (coords + u) / nstrat


def adapt_nh(d_h: jax.Array, beta, neval: int, n_min: int = 2) -> jax.Array:
    """Re-allocate evals per cube: n_h = max(n_min, floor(neval * p_h)) with
    p_h = d_h^beta / sum d_h^beta (paper's damped stratification update)."""
    d_h = jnp.maximum(d_h, 0.0)
    p = d_h ** beta
    tot = jnp.sum(p)
    p = jnp.where(tot > 0, p / jnp.maximum(tot, 1e-30), 1.0 / d_h.shape[0])
    return jnp.maximum(jnp.floor(neval * p), n_min).astype(jnp.int32)
