"""The fill phase: sample -> transform -> evaluate -> accumulate.

This is cuVegas' ``vegasFill`` (Alg. 2) — the kernel that dominates runtime
(paper Table 1: 36-99% of total).  The decomposition is the paper's C1:
a flat axis of ``n_cap`` evaluations, each knowing its hypercube, processed
in fixed-size batches so the work per lane is identical (no divergence).

Three interchangeable backends with one contract:
  * ``ref``    — pure jnp oracle (scatter-add accumulation),
  * ``pallas`` — the TPU kernel (kernels/vegas_fill.py) for transform + eval +
                 MXU one-hot map accumulation; cube reduction via segment-sum,
  * both are chunked with ``lax.scan`` so the live working set stays bounded
    (the TPU analogue of the paper's batch_size knob).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import map as vmap_
from . import strat


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FillResult:
    """Accumulators produced by one fill pass (paper's map/cube weights)."""
    map_sums: jax.Array    # (d, ninc)   sum of (J f)^2 per map interval
    map_counts: jax.Array  # (d, ninc)   number of samples per map interval
    cube_s1: jax.Array     # (n_cubes,)  sum of J f per hypercube
    cube_s2: jax.Array     # (n_cubes,)  sum of (J f)^2 per hypercube

    def tree_flatten(self):
        return (self.map_sums, self.map_counts, self.cube_s1, self.cube_s2), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def __add__(self, other):
        return FillResult(self.map_sums + other.map_sums,
                          self.map_counts + other.map_counts,
                          self.cube_s1 + other.cube_s1,
                          self.cube_s2 + other.cube_s2)


def _eval_chunk(edges, cube, u, integrand, nstrat, n_cubes):
    """Transform + evaluate one chunk. Returns (w, iy, valid)."""
    valid = cube < n_cubes
    y = strat.stratified_y(jnp.minimum(cube, n_cubes - 1), u, nstrat)
    x, jac, iy = vmap_.apply_map(edges, y)
    fx = integrand(x)
    w = jnp.where(valid, jac * fx, 0.0)
    return w, iy, valid


def fill_reference(edges, n_h, key, integrand, *, nstrat: int, n_cap: int,
                   chunk: int, dtype=jnp.float32, accum_dtype=None,
                   start_chunk=0, n_chunks: int | None = None,
                   kahan: bool = False,
                   return_comp: bool = False) -> FillResult:
    """Pure-jnp fill, scanned in chunks of the *global* eval axis.

    ``start_chunk``/``n_chunks`` select a contiguous chunk range — the unit of
    distribution.  The RNG is keyed by the GLOBAL chunk index, so the stream a
    shard produces is a pure function of (key, chunk id): any device can
    (re)compute any shard — the basis for elastic scaling and straggler
    re-dispatch (DESIGN.md C5/D3).

    ``kahan=True`` carries a Kahan compensation term through the scan, making
    the accumulated sums independent (to ~1 ulp) of how the chunk range is
    grouped.  The sharded fill turns this on so a fill split over 2 devices
    and one split over 8 agree far inside the 2e-5 invariance tolerance —
    without it, plain-f32 reduction-order drift is amplified by the adaptation
    feedback across iterations (DESIGN.md §5).

    ``accum_dtype`` (default: ``dtype``) is the §15 accumulation dtype:
    samples and integrand products stay in ``dtype``, but each chunk's
    contributions are widened BEFORE the scatter-adds, so both the
    within-chunk and the cross-chunk accumulation run at the wider
    precision — the reference semantics the kernel backends approximate.

    ``return_comp=True`` (requires ``kahan=True``) returns the
    ``(sums, compensation)`` FillResult pair instead of the sums alone: the
    shard boundary needs BOTH so the psum can carry the compensation across
    devices (``engine.sharding.make_local_fill``) instead of silently
    degrading to naive summation there.
    """
    if return_comp and not kahan:
        raise ValueError("return_comp=True requires kahan=True (there is "
                         "no compensation term to return)")
    accum = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else jnp.dtype(dtype)
    dim = edges.shape[0]
    ninc = edges.shape[1] - 1
    n_cubes = n_h.shape[0]
    assert n_cap % chunk == 0, (n_cap, chunk)
    if n_chunks is None:
        n_chunks = n_cap // chunk

    def body(carry, step):
        acc, comp = carry if kahan else (carry, None)
        gchunk = start_chunk + step
        k = jax.random.fold_in(key, gchunk)
        u = jax.random.uniform(k, (chunk, dim), dtype=dtype)
        cube = strat.cubes_for_slice(n_h, gchunk * chunk, chunk)
        w, iy, valid = _eval_chunk(edges, cube, u, integrand, nstrat, n_cubes)
        w = w.astype(accum)
        w2 = w * w
        cnt = valid.astype(accum)
        ms, mc = vmap_.accumulate_map_weights(iy, w2, cnt, ninc)
        # Overflow bucket (id n_cubes) catches masked evals; dropped below.
        s1 = jnp.zeros((n_cubes + 1,), accum).at[cube].add(w)
        s2 = jnp.zeros((n_cubes + 1,), accum).at[cube].add(w2)
        contrib = FillResult(ms, mc, s1[:n_cubes], s2[:n_cubes])
        if not kahan:
            return acc + contrib, None
        y = jax.tree.map(jnp.subtract, contrib, comp)
        t = jax.tree.map(jnp.add, acc, y)
        comp = jax.tree.map(lambda tt, a, yy: (tt - a) - yy, t, acc, y)
        return (t, comp), None

    zero = FillResult(jnp.zeros((dim, ninc), accum), jnp.zeros((dim, ninc), accum),
                      jnp.zeros((n_cubes,), accum), jnp.zeros((n_cubes,), accum))
    init = (zero, zero) if kahan else zero
    out, _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    if kahan:
        return out if return_comp else out[0]
    return out


def fill_pallas(edges, n_h, key, integrand, *, nstrat: int, n_cap: int,
                chunk: int, dtype=jnp.float32, accum_dtype=None,
                interpret: bool | None = None,
                fused_cubes: bool = True, tile: int | None = None,
                start_chunk=0, n_chunks: int | None = None,
                kahan: bool = False, return_comp: bool = False,
                rng_in_kernel: bool | None = None) -> FillResult:
    """Pallas-kernel fill, scan-chunked like :func:`fill_reference` (same
    ``start_chunk``/``n_chunks`` distribution unit, same chunk-keyed RNG with
    bit-identical streams).  ``fused_cubes=True`` (default) runs the P-V3
    streaming kernel: in-kernel RNG + in-kernel cube accumulation, no per-eval
    array anywhere.  ``interpret=None`` autodetects (compiled on TPU,
    interpreter elsewhere); ``tile=None`` autotunes against the VMEM budget."""
    from repro.kernels import ops as kops
    return kops.fill(edges, n_h, key, integrand, nstrat=nstrat, n_cap=n_cap,
                     chunk=chunk, dtype=dtype, accum_dtype=accum_dtype,
                     interpret=interpret,
                     fused_cubes=fused_cubes, tile=tile,
                     start_chunk=start_chunk, n_chunks=n_chunks, kahan=kahan,
                     return_comp=return_comp, rng_in_kernel=rng_in_kernel)


def fill_pallas_gpu(edges, n_h, key, integrand, *, nstrat: int, n_cap: int,
                    chunk: int, dtype=jnp.float32, accum_dtype=None,
                    interpret: bool | None = None, block: int | None = None,
                    num_warps: int | None = None, start_chunk=0,
                    n_chunks: int | None = None, kahan: bool = False,
                    return_comp: bool = False,
                    rng_in_kernel: bool | None = None) -> FillResult:
    """Triton-lowered fill (the ``pallas-gpu`` registry backend): grid over
    sample blocks, block-privatized histograms flushed with atomic adds,
    scatter-style cube accumulation — the fused kernel reshaped for a GPU
    memory hierarchy instead of an MXU (DESIGN.md §14).  Same scan-chunked
    contract and bit-identical chunk-keyed RNG as the other backends;
    ``interpret=None`` autodetects (compiled Triton on GPU, interpreter
    elsewhere), ``block=None`` autotunes against the shared-memory budget."""
    from repro.kernels import gpu_fill
    return gpu_fill.fill(edges, n_h, key, integrand, nstrat=nstrat,
                         n_cap=n_cap, chunk=chunk, dtype=dtype,
                         accum_dtype=accum_dtype,
                         interpret=interpret, block=block,
                         num_warps=num_warps, start_chunk=start_chunk,
                         n_chunks=n_chunks, kahan=kahan,
                         return_comp=return_comp,
                         rng_in_kernel=rng_in_kernel)


# Backend selection lives in the capability-declaring registry
# (repro.engine.backends): 'ref' -> fill_reference, 'pallas' (P-V2) and
# 'pallas-fused' (P-V3) -> fill_pallas with the fusion knob pinned,
# 'pallas-gpu' -> fill_pallas_gpu (the Triton lowering).


def estimate_from_cubes(res: FillResult, n_h: jax.Array):
    """Iteration estimate + variance + stratification signal (eq. (5)-(7)).

    Each cube has y-volume v = 1/n_cubes; I_h = v * mean(Jf), and the variance
    of the cube mean is v^2 (E[w^2]-E[w]^2)/(n_h-1).
    Returns (I_it, sigma2_it, d_h) with d_h = per-cube sample sigma — the
    allocation signal n_h ∝ d_h^beta ("n_h proportional to sigma_h(Jf)").
    """
    n_cubes = n_h.shape[0]
    nh = jnp.maximum(n_h.astype(res.cube_s1.dtype), 1.0)
    v = 1.0 / n_cubes
    m = res.cube_s1 / nh
    q = res.cube_s2 / nh
    var = jnp.maximum(q - m * m, 0.0)
    i_it = v * jnp.sum(m)
    sigma2 = v * v * jnp.sum(var / jnp.maximum(nh - 1.0, 1.0))
    d_h = jnp.sqrt(var)
    return i_it, sigma2, d_h
