"""Test integrands (paper Table 3) plus the two application integrands
(Asian option, eq. (10)-(11); Feynman path integral, eq. (12)-(13)).

Every integrand is a pure function ``f(x) -> (n,)`` over a batch ``x (n, d)``
and carries its integration bounds and dimension via :class:`Integrand`.
These are traced into the Pallas fill kernel at compile time — the JAX
analogue of cuVegas' Numba-compiled device functions (DESIGN.md C7).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Integrand:
    name: str
    dim: int
    fn: Callable[[jax.Array], jax.Array]
    lower: tuple
    upper: tuple
    target: float | None = None  # analytic value of the integral, if known

    def __call__(self, x):
        return self.fn(x)


def _unit(name, dim, fn, target):
    return Integrand(name, dim, fn, (0.0,) * dim, (1.0,) * dim, target)


# --- Table 3 -----------------------------------------------------------------

def make_sine_exp():
    # (1) f = sin(x1) + exp(x2), 2D. Integral = (1 - cos 1) + (e - 1).
    target = (1.0 - math.cos(1.0)) + (math.e - 1.0)
    return _unit("sine_exp", 2, lambda x: jnp.sin(x[:, 0]) + jnp.exp(x[:, 1]), target)


def make_linear(dim=10):
    # (2) f = sum x_i. Integral = d/2.
    return _unit("linear", dim, lambda x: jnp.sum(x, axis=-1), dim / 2.0)


def make_cosine(dim=10):
    # (3) f = prod cos(x_i). Integral = sin(1)^d.
    return _unit("cosine", dim, lambda x: jnp.prod(jnp.cos(x), axis=-1),
                 math.sin(1.0) ** dim)


def make_exponential(dim=10):
    # (4) f = exp(sum x_i^2). Integral = (sqrt(pi)/2 * erfi(1))^d.
    from scipy.special import erfi  # target only; not traced
    target = float((math.sqrt(math.pi) / 2.0 * erfi(1.0)) ** dim)
    return _unit("exponential", dim,
                 lambda x: jnp.exp(jnp.sum(x * x, axis=-1)), target)


def make_roos_arnold(dim=10):
    # (5) f = prod |4 x_i - 2|. Integral = 1.
    return _unit("roos_arnold", dim,
                 lambda x: jnp.prod(jnp.abs(4.0 * x - 2.0), axis=-1), 1.0)


def make_morokoff_caflisch(dim=8):
    # (6) f = (1 + 1/d)^d prod x_i^(1/d). Integral = 1.
    c = (1.0 + 1.0 / dim) ** dim

    def fn(x):
        # x^(1/d) via exp/log with a 0-guard (x=0 has measure zero).
        return c * jnp.exp(jnp.sum(jnp.log(jnp.maximum(x, 1e-30)), axis=-1) / dim)

    return _unit("morokoff_caflisch", dim, fn, 1.0)


def make_gaussian(dim=4, mu=0.5, sigma=0.01):
    # (7) sharply peaked product Gaussian. Integral = prod_i erf-window ~= 1.
    norm = 1.0 / (2.0 * math.pi * sigma**2) ** (dim / 2.0)
    target = float(math.erf((1.0 - mu) / (sigma * math.sqrt(2.0))) / 2.0
                   + math.erf(mu / (sigma * math.sqrt(2.0))) / 2.0) ** dim

    def fn(x):
        return norm * jnp.exp(-jnp.sum((x - mu) ** 2, axis=-1) / (2.0 * sigma**2))

    return _unit("gaussian", dim, fn, target)


def make_ridge(dim=4, n_peaks=1000):
    # (8) "Ridge": sum of n_peaks Gaussians centred along the main diagonal —
    # the computationally intensive, diagonal-structured integrand VEGAS+'s
    # stratification was designed for.
    centers = jnp.linspace(0.0, 1.0, n_peaks)
    scale = 10000.0 / (math.pi**2 * n_peaks)

    def fn(x):
        # (n, 1, d) - (P,) broadcast over the shared diagonal center.
        d2 = jnp.sum((x[:, None, :] - centers[None, :, None]) ** 2, axis=-1)
        return scale * jnp.sum(jnp.exp(-100.0 * d2), axis=-1)

    # target: sum_i prod_j int_0^1 exp(-100 (x - c_i)^2) dx, per-dim closed form.
    c = jnp.asarray(centers, jnp.float64) if jax.config.jax_enable_x64 else centers
    import numpy as np
    from scipy.special import erf
    cn = np.linspace(0.0, 1.0, n_peaks)
    per_dim = (math.sqrt(math.pi) / 20.0) * (erf(10.0 * (1.0 - cn)) + erf(10.0 * cn))
    target = float(scale * np.sum(per_dim**dim))
    return _unit(f"ridge", dim, fn, target)


# --- Applications ------------------------------------------------------------

def make_asian_option(n_steps=16, s0=100.0, strike=100.0, r=0.1, sigma=0.2,
                      t_mat=1.0, geometric=False):
    """Arithmetic(default)/geometric Asian call (paper eq. (10)-(11)).

    d = n_steps uniforms are mapped to standard normals via the inverse-erf,
    driving a discretized GBM path; payoff is discounted average-vs-strike.
    The geometric variant has a Black-Scholes-type closed form used as the
    validation target (targets.asian_geometric_closed_form).
    """
    dt = t_mat / n_steps
    drift = (r - 0.5 * sigma**2) * dt
    vol = sigma * math.sqrt(dt)

    def fn(x):
        # Clamp away from {0,1}: erfinv is singular there (measure zero).
        # The bound must survive float32 rounding (1 - 1e-7 rounds to 1.0f).
        eps = 1e-6 if x.dtype == jnp.float32 else 1e-12
        xc = jnp.clip(x, eps, 1.0 - eps)
        z = jax.scipy.special.erfinv(2.0 * xc - 1.0) * math.sqrt(2.0)
        logret = drift + vol * z                       # (n, d) per-step log-returns
        logpath = jnp.cumsum(logret, axis=-1)          # (n, d) log S_k/S0
        if geometric:
            avg = s0 * jnp.exp(jnp.mean(logpath, axis=-1))
        else:
            avg = jnp.mean(s0 * jnp.exp(logpath), axis=-1)
        return math.exp(-r * t_mat) * jnp.maximum(avg - strike, 0.0)

    name = "asian_geo" if geometric else "asian"
    from .targets import asian_geometric_closed_form
    target = asian_geometric_closed_form(s0, strike, r, sigma, t_mat, n_steps) \
        if geometric else None
    return Integrand(name, n_steps, fn, (0.0,) * n_steps, (1.0,) * n_steps, target)


def make_feynman_path(n_slices=9, t_total=4.0, mass=1.0, x_end=0.0, box=5.0):
    """Harmonic-oscillator lattice path integral <x|e^{-HT}|x> (eq. (12)-(13)).

    d = N-1 interior points; V(x) = x^2/2. The lattice action is a quadratic
    form, so the (untruncated) integral is Gaussian-exact:
    A (2 pi)^{(N-1)/2} / sqrt(det M) — used as target.
    """
    n = n_slices
    dim = n - 1
    a = t_total / n
    amp = (mass / (2.0 * math.pi * a)) ** (n / 2.0)

    def fn(x):
        xp = jnp.pad(x, ((0, 0), (1, 1)), constant_values=x_end)  # endpoints
        kin = (mass / (2.0 * a)) * jnp.sum((xp[:, 1:] - xp[:, :-1]) ** 2, axis=-1)
        pot = a * jnp.sum(0.5 * xp[:, :-1] ** 2, axis=-1)  # j = 0..N-1
        return amp * jnp.exp(-(kin + pot))

    import numpy as np
    k = 2.0 * np.eye(dim) - np.eye(dim, k=1) - np.eye(dim, k=-1)
    m_mat = (mass / a) * k + a * np.eye(dim)  # + aV''-> a for V = x^2/2
    target = float(amp * (2.0 * math.pi) ** (dim / 2.0)
                   / math.sqrt(np.linalg.det(m_mat)))
    return Integrand("feynman_path", dim, fn, (-box,) * dim, (box,) * dim, target)


TABLE3 = {
    1: make_sine_exp,
    2: make_linear,
    3: make_cosine,
    4: make_exponential,
    5: make_roos_arnold,
    6: make_morokoff_caflisch,
    7: make_gaussian,
    8: make_ridge,
}


def table3_suite(ridge_peaks=1000):
    """The seven benchmark integrands of §4.3 (1-7; Ridge excluded there) plus
    Ridge for the breakdown/stratification experiments."""
    return [make_sine_exp(), make_linear(), make_cosine(), make_exponential(),
            make_roos_arnold(), make_morokoff_caflisch(), make_gaussian(),
            make_ridge(n_peaks=ridge_peaks)]
