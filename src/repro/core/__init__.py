"""VEGAS+ core: the paper's contribution as a composable JAX module."""

from .integrands import Integrand, table3_suite  # noqa: F401
from .integrator import (VegasConfig, VegasResult, VegasState,  # noqa: F401
                         adapt_loop, eval_phase, run, run_loop)
