"""VEGAS+ core: the paper's contribution as a composable JAX module."""

from .integrands import Integrand, table3_suite  # noqa: F401
from .integrator import VegasConfig, VegasResult, VegasState, run  # noqa: F401
