"""VEGAS+ driver: iterate fill -> adapt -> aggregate (paper Alg. 1).

The whole iteration (fill, stratification update, map update, estimate) is a
single jitted program — the JAX realization of cuVegas' "everything stays on
device" design (C4/C6): there are no host transfers inside an iteration, and
XLA overlaps the map update with result aggregation (the paper used two CUDA
streams for this).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable

import jax
import jax.numpy as jnp

from repro.engine.config import LEGACY_EXEC_FIELDS, ExecutionConfig

from . import fill as fill_mod
from . import map as vmap_
from . import strat
from .integrands import Integrand

_ALGO_FIELDS = (
    ("neval", 100_000),       # target integrand evaluations / iteration
    ("max_it", 20),           # max_it
    ("skip", 0),              # iterations excluded from the final combine
    ("ninc", 1024),           # n_intervals of the importance map
    ("alpha", 0.5),           # importance-map damping
    ("beta", 0.75),           # stratification damping (0 => classic VEGAS)
    ("nstrat", None),         # stratifications/dim (None => heuristic)
    ("max_cubes", 1 << 18),   # cap on nstrat**d
    ("chunk", 16_384),        # evals per scanned chunk (batch_size analog)
    ("dtype", "float32"),
)


@dataclasses.dataclass(frozen=True, init=False)
class VegasConfig:
    """Algorithm parameters (paper Table 2 names where they exist) plus ONE
    execution handle: ``execution`` (`repro.engine.ExecutionConfig`) carries
    everything about HOW the run executes — backend, kernel knobs, batching,
    sharding, checkpointing (DESIGN.md §9).

    Deprecation shim: the pre-engine flat fields (``backend``, ``interpret``,
    ``fused_cubes``, ``tile``) are still accepted as keyword arguments (with
    a DeprecationWarning) and folded into ``execution``; reading them back
    (``cfg.backend`` etc.) keeps working via properties.
    """
    neval: int = 100_000
    max_it: int = 20
    skip: int = 0
    ninc: int = 1024
    alpha: float = 0.5
    beta: float = 0.75
    nstrat: int | None = None
    max_cubes: int = 1 << 18
    chunk: int = 16_384
    dtype: str = "float32"
    execution: ExecutionConfig = ExecutionConfig()

    def __init__(self, *args, execution: ExecutionConfig | None = None,
                 **kwargs):
        names = [n for n, _ in _ALGO_FIELDS]
        if len(args) > len(names):
            raise TypeError(f"VegasConfig takes at most {len(names)} "
                            f"positional arguments ({len(args)} given)")
        vals = dict(_ALGO_FIELDS)
        positional = dict(zip(names, args))
        vals.update(positional)
        legacy = {}
        for k, v in kwargs.items():
            if k in positional:
                raise TypeError(f"duplicate argument {k!r}")
            if k in vals:
                vals[k] = v
            elif k in LEGACY_EXEC_FIELDS:
                legacy[k] = v
            else:
                raise TypeError(f"unexpected argument {k!r}")
        if legacy:
            warnings.warn(
                f"VegasConfig({', '.join(sorted(legacy))}) is deprecated: "
                f"execution knobs moved to "
                f"VegasConfig(execution=ExecutionConfig(...))",
                DeprecationWarning, stacklevel=2)
            execution = (execution or ExecutionConfig()).with_legacy(**legacy)
        for k, v in vals.items():
            object.__setattr__(self, k, v)
        object.__setattr__(self, "execution", execution or ExecutionConfig())

    # Read-side back-compat for the old flat fields.
    @property
    def backend(self) -> str:
        return self.execution.backend

    @property
    def interpret(self) -> bool | None:
        return self.execution.interpret

    @property
    def fused_cubes(self) -> bool:
        return self.execution.backend == "pallas-fused"

    @property
    def tile(self) -> int | None:
        return self.execution.tile

    def with_execution(self, execution: ExecutionConfig) -> "VegasConfig":
        return dataclasses.replace(self, execution=execution)

    def resolve(self, dim: int) -> "ResolvedConfig":
        ns = self.nstrat or strat.choose_nstrat(self.neval, dim, self.max_cubes)
        n_cubes = ns**dim
        n_cap = strat.eval_capacity(self.neval, n_cubes)
        chunk = min(self.chunk, max(n_cap, 256))
        n_cap = ((n_cap + chunk - 1) // chunk) * chunk  # pad to chunk multiple
        return ResolvedConfig(self, dim, ns, n_cubes, n_cap, chunk)


@dataclasses.dataclass(frozen=True)
class ResolvedConfig:
    base: VegasConfig
    dim: int
    nstrat: int
    n_cubes: int
    n_cap: int
    chunk: int

    def __getattr__(self, name):
        return getattr(self.base, name)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class VegasState:
    """Everything the algorithm carries across iterations. O(KB): this is the
    checkpoint payload for fault-tolerant runs (DESIGN.md §5)."""
    edges: jax.Array      # (d, ninc+1) importance map
    n_h: jax.Array        # (n_cubes,) evals per hypercube
    key: jax.Array        # base PRNG key
    it: jax.Array         # iteration counter
    results: jax.Array    # (max_it, 2): per-iteration (I_i, sigma2_i)

    def tree_flatten(self):
        return (self.edges, self.n_h, self.key, self.it, self.results), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class VegasResult:
    mean: float
    sdev: float
    chi2_dof: float
    n_it: int             # iterations entering the combination (n_used)
    iter_means: jax.Array
    iter_sdevs: jax.Array
    state: VegasState
    n_it_used: int = 0    # iterations actually executed (< max_it when a
                          # StopPolicy converged the run early, §10)

    def __repr__(self):
        return (f"VegasResult(mean={self.mean:.8g}, sdev={self.sdev:.3g}, "
                f"chi2/dof={self.chi2_dof:.2f}, n_it={self.n_it}, "
                f"n_it_used={self.n_it_used})")


def init_state(integrand: Integrand, cfg: ResolvedConfig, key) -> VegasState:
    dtype = jnp.dtype(cfg.dtype)
    edges = vmap_.uniform_edges(integrand.lower, integrand.upper, cfg.ninc, dtype)
    n_h = strat.uniform_nh(cfg.neval, cfg.n_cubes)
    results = jnp.stack([jnp.zeros((cfg.max_it,), dtype),
                         jnp.full((cfg.max_it,), jnp.inf, dtype)], axis=1)
    return VegasState(edges, n_h, key, jnp.zeros((), jnp.int32), results)


def iteration_step(state: VegasState, integrand: Integrand,
                   cfg: ResolvedConfig, fill_fn=None) -> VegasState:
    """One VEGAS+ iteration. ``fill_fn`` lets the engine (or a custom
    caller) substitute the fill — e.g. the shard_mapped multi-device fill —
    while reusing adaptation/aggregation unchanged.  The default comes from
    the capability-declaring backend registry (`repro.engine.backends`)."""
    dtype = jnp.dtype(cfg.dtype)
    key_it = jax.random.fold_in(state.key, state.it)
    if fill_fn is None:
        from repro.engine import backends as _backends
        fill_fn = _backends.bind_fill(cfg)
    res = fill_fn(state.edges, state.n_h, key_it, integrand)

    i_it, sigma2_it, d_h = fill_mod.estimate_from_cubes(res, state.n_h)
    results = state.results.at[state.it].set(
        jnp.stack([i_it.astype(dtype), sigma2_it.astype(dtype)]))

    # Adaptive stratification (the "+" of VEGAS+); beta=0 freezes n_h uniform.
    n_h = (strat.adapt_nh(d_h, cfg.beta, cfg.neval)
           if cfg.beta > 0 else state.n_h)
    # Importance-map adaptation; alpha=0 freezes the map.  Widened (§15)
    # moments would promote the adapted edges to the accum dtype — cast back
    # so the loop-carried state (and next iteration's samples) stay in the
    # sample dtype.
    edges = (vmap_.adapt_edges(state.edges, res.map_sums, res.map_counts,
                               cfg.alpha).astype(dtype)
             if cfg.alpha > 0 else state.edges)
    return VegasState(edges, n_h, state.key, state.it + 1, results)


def combine_results(results: jax.Array, skip: int, n_done: int):
    """Inverse-variance weighted combination across iterations (eq. (8)-(9))
    plus the chi^2/dof consistency diagnostic vegas reports.

    Sentinel contract (§10): the results buffer is always fixed-shape
    ``(max_it, 2)``; iterations the loop never executed keep the
    ``(0.0, inf)`` fill from ``init_state``.  Slots with index ``>= n_done``
    are excluded by the explicit ``idx < n_done`` mask — and even if a slot
    past ``n_done`` held finite garbage it could not leak in — while the
    ``isfinite`` guard independently drops the inf sentinels, so the stats
    ignore unfilled slots for every ``n_done < max_it``
    (tests/test_early_stop.py proves both properties).  ``n_done`` may be a
    traced scalar (the adaptive while_loop evaluates this every iteration).

    Degenerate case: when no iteration is usable (every sig2 is inf or
    non-finite, so ``wsum == 0``) the result is the NaN-free sentinel
    ``(0.0, inf, 0.0, 0)`` — zero information, not a silent NaN.

    Differentiation contract (§11): every consumer that differentiates
    through this function (the grad module's running-stat paths, user code
    taking ``jax.grad`` of a combined estimate) relies on the double-where
    idiom below: each ``1/x`` whose operand can be the 0-or-inf sentinel is
    guarded INSIDE its selecting ``where``, so the unused branch never
    produces the ``0 * inf = NaN`` that reverse-mode would otherwise
    propagate into the gradients of early-stopped runs (whose results
    buffer keeps ``(0.0, inf)`` sentinel rows past ``n_done``).
    tests/test_grad.py::test_combine_results_grad_nan_safe is the
    regression.
    """
    means, sig2 = results[:, 0], results[:, 1]
    idx = jnp.arange(results.shape[0])
    use = (idx >= skip) & (idx < n_done) & jnp.isfinite(sig2) & (sig2 > 0)
    wts = jnp.where(use, 1.0 / jnp.where(use, sig2, 1.0), 0.0)
    wsum = jnp.sum(wts)
    any_used = wsum > 0
    mean = jnp.where(any_used,
                     jnp.sum(wts * means) / jnp.where(any_used, wsum, 1.0), 0.0)
    # inf when nothing was usable — via the guarded branch, NOT a bare
    # 1/wsum: d(1/wsum) at wsum=0 is -inf, and inf * the zero cotangent of
    # the unselected branch would NaN-poison grads of early-stopped runs.
    var = jnp.where(any_used, 1.0 / jnp.where(any_used, wsum, 1.0), jnp.inf)
    n_used = jnp.sum(use)
    chi2 = jnp.sum(jnp.where(use, wts * (means - mean) ** 2, 0.0))
    chi2_dof = jnp.where(any_used, chi2 / jnp.maximum(n_used - 1, 1), 0.0)
    return mean, jnp.sqrt(var), chi2_dof, n_used


def run_loop(state: VegasState, integrand: Integrand, cfg: ResolvedConfig,
             start: int, fill_fn=None, *, stop=None,
             stop_sync=None, it_cap=None) -> VegasState:
    """The ADAPT phase: the whole iteration loop as one traced program.

    Fixed-length mode (no active stop policy): ``lax.fori_loop`` over
    :func:`iteration_step` from ``start`` to ``cfg.max_it``.  This is the
    jitted single-program path of ``run`` (no host sync between iterations,
    DESIGN.md B1) and the unit the batch engine ``vmap``s over scenarios
    (``repro.batch.engine``).  ``iteration_step`` keys its RNG and results
    slot off ``state.it``, so looping over it is bit-identical to stepping
    it from a host loop (checked by tests/test_determinism.py).

    Adaptive mode (``stop`` is an active `repro.engine.StopPolicy`, §10):
    the same ``iteration_step`` under a fixed-shape ``lax.while_loop``.  The
    carry is ``(state, running stats, continue?)`` where the running
    ``(mean, sdev, chi2_dof)`` are re-derived from the results buffer by
    :func:`combine_results` after every iteration; the loop exits once the
    combined sdev meets ``max(rtol * |mean|, atol)`` (never before
    ``stop.min_it``) or ``max_it`` is reached.  Nothing about the state's
    shape changes — the ``(max_it, 2)`` buffer keeps its ``sigma2 = inf``
    sentinels past ``state.it`` — so the program stays jittable, resumes
    from fixed-loop checkpoints (the running stats are a pure function of
    the carried results buffer, so a resume re-derives them exactly), and
    ``vmap``s: under the while_loop batching rule, scenarios whose predicate
    went false keep their old carry via ``select`` — converged lanes become
    no-op iterations while stragglers continue, one shared trace.

    ``stop_sync`` (optional) reduces the per-iteration continue decision
    across mesh axes when the loop itself runs inside a ``shard_map``
    (`engine.sharding.make_stop_sync`): every shard computes the identical
    replicated statistics, and the explicit all-agree reduction guarantees
    the loop cannot diverge across devices.

    ``it_cap`` (optional, §12) is the time-budget stopping input: a traced
    iteration-count cap — the serving layer derives it from a request's
    wall-clock budget and the measured per-iteration cost.  It rides the
    while_loop carry next to the running stats, so the loop exits at
    ``it >= min(max_it, it_cap)`` even when no precision target is set (a
    budget-only run still uses the while_loop), and under ``vmap`` a
    per-scenario cap array gives every lane its own budget.  The cap is a
    HARD ceiling: it wins over ``min_it`` (a spent budget must stop the run
    even if the policy would rather keep adapting).
    """
    if stop is None:
        stop = getattr(cfg.execution, "stop", None)
    if stop is not None and not stop.active:
        stop = None
    if stop is None and it_cap is None:
        return jax.lax.fori_loop(
            start, cfg.max_it,
            lambda _, s: iteration_step(s, integrand, cfg, fill_fn), state)

    def running_stats(s):
        mean, sdev, chi2_dof, _ = combine_results(s.results, cfg.skip, s.it)
        return mean, sdev, chi2_dof

    def wants_more(s, stats, cap):
        mean, sdev, _ = stats
        cont = s.it < jnp.minimum(cfg.max_it, cap)
        if stop is not None:
            cont = cont & ~stop.converged(mean, sdev, s.it)
        if stop_sync is not None:
            cont = stop_sync(cont)
        return cont

    # The running stats and the iteration cap ride the carry next to the
    # continue flag: cond reads only the flag (the decision is made in the
    # body, where stop_sync can psum it), while the carried (mean, sdev,
    # chi2_dof) keep the §10 contract that the stop statistics live
    # alongside the state — inspectable mid-loop and re-derivable on resume.
    cap = jnp.asarray(cfg.max_it if it_cap is None else it_cap, jnp.int32)

    def body(carry):
        s, _, cap, _ = carry
        s = iteration_step(s, integrand, cfg, fill_fn)
        stats = running_stats(s)
        return s, stats, cap, wants_more(s, stats, cap)

    stats0 = running_stats(state)
    carry = (state, stats0, cap, wants_more(state, stats0, cap))
    state, _, _, _ = jax.lax.while_loop(lambda c: c[3], body, carry)
    return state


#: The two-phase split (§11): ``adapt_loop`` is `run_loop` under its phase
#: name — the part of a differentiable run that executes with gradients
#: stopped — and :func:`eval_phase` is the frozen-map pass whose pathwise
#: gradient is exact Monte Carlo.
adapt_loop = run_loop


def eval_key(key, cfg: ResolvedConfig):
    """RNG key of the frozen-map evaluation pass: ``fold_in(key, max_it)``.

    Adapt iterations consume ``fold_in(key, it)`` for ``it < max_it``
    (`iteration_step`), so the ``max_it`` slot is never drawn by the adapt
    phase — the eval pass gets a deterministic stream independent of every
    adapt iteration, whether or not a StopPolicy truncated the loop.
    """
    return jax.random.fold_in(key, cfg.max_it)


def eval_phase(edges, n_h, integrand: Integrand, cfg: ResolvedConfig, key,
               fill_fn=None):
    """The EVAL phase of a two-phase run (§11): one fill over a FROZEN map.

    ``edges``/``n_h`` are the converged (and, in a differentiable run,
    ``stop_gradient``-frozen) map and stratification; the pass neither
    adapts nor touches the results buffer.  Returns the pass's
    ``(estimate, sigma2)`` from :func:`fill.estimate_from_cubes` — for a
    fixed map this is an unbiased estimate of the integral whatever the
    map, which is exactly why the adapt phase's parameter-dependence can be
    dropped from the gradient (DESIGN.md §11).  Pure jnp when ``fill_fn``
    binds the ``ref`` backend, hence differentiable w.r.t. anything the
    integrand or ``edges`` carry (`repro.grad` builds on this).
    """
    if fill_fn is None:
        from repro.engine import backends as _backends
        fill_fn = _backends.bind_fill(cfg)
    res = fill_fn(edges, n_h, key, integrand)
    i_ev, sigma2_ev, _ = fill_mod.estimate_from_cubes(res, n_h)
    return i_ev, sigma2_ev


def run(integrand: Integrand, cfg: VegasConfig | None = None, *,
        key=None, fill_fn=None, state: VegasState | None = None,
        checkpoint_cb: Callable[[int, VegasState], None] | None = None) -> VegasResult:
    """Run VEGAS+ to completion (or resume from ``state``).

    Thin adapter over the execution engine: ``make_plan`` validates the
    config's execution axes (backend/sharding/checkpoint/stop,
    `repro.engine`) and ``execute`` runs the plan.  With no checkpoint
    policy the whole loop executes as a single jitted on-device program
    (``run_loop``): zero host round-trips between iterations.  An active
    ``ExecutionConfig(stop=StopPolicy(...))`` ends the loop as soon as the
    combined sdev meets the target — ``VegasResult.n_it_used`` reports how
    many iterations actually ran (§10).

    Legacy extension hooks, forwarded to the executor unchanged:
    ``fill_fn(edges, n_h, key_it, integrand) -> FillResult`` replaces the
    plan's fill wiring entirely (prefer ``ExecutionConfig(mesh=...)``);
    ``checkpoint_cb(it, state)`` forces the host-side loop and is invoked
    after every iteration (prefer ``ExecutionConfig(checkpoint=
    CheckpointPolicy(...))``).  Resume by passing the restored ``state``
    (the results buffer grows automatically if the resuming config has a
    larger ``max_it``).
    """
    from repro.engine import execute, make_plan
    plan = make_plan(integrand, cfg)
    return execute(plan, key=key, state=state, fill_fn=fill_fn,
                   checkpoint_cb=checkpoint_cb)
