"""VEGAS+ driver: iterate fill -> adapt -> aggregate (paper Alg. 1).

The whole iteration (fill, stratification update, map update, estimate) is a
single jitted program — the JAX realization of cuVegas' "everything stays on
device" design (C4/C6): there are no host transfers inside an iteration, and
XLA overlaps the map update with result aggregation (the paper used two CUDA
streams for this).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from . import fill as fill_mod
from . import map as vmap_
from . import strat
from .integrands import Integrand


@dataclasses.dataclass(frozen=True)
class VegasConfig:
    """Algorithm parameters (paper Table 2 names where they exist)."""
    neval: int = 100_000          # target integrand evaluations / iteration
    max_it: int = 20              # max_it
    skip: int = 0                 # iterations excluded from the final combine
    ninc: int = 1024              # n_intervals of the importance map
    alpha: float = 0.5            # importance-map damping
    beta: float = 0.75            # stratification damping (0 => classic VEGAS)
    nstrat: int | None = None     # stratifications/dim (None => heuristic)
    max_cubes: int = 1 << 18      # cap on nstrat**d
    chunk: int = 16_384           # evals per scanned chunk (batch_size analog)
    dtype: str = "float32"
    backend: str = "ref"          # 'ref' | 'pallas'
    interpret: bool | None = None  # None => autodetect (kernels.backend_default)
    fused_cubes: bool = True      # in-kernel RNG + cube accumulation (P-V3)
    tile: int | None = None       # pallas tile; None => VMEM-budget autotune

    def resolve(self, dim: int) -> "ResolvedConfig":
        ns = self.nstrat or strat.choose_nstrat(self.neval, dim, self.max_cubes)
        n_cubes = ns**dim
        n_cap = strat.eval_capacity(self.neval, n_cubes)
        chunk = min(self.chunk, max(n_cap, 256))
        n_cap = ((n_cap + chunk - 1) // chunk) * chunk  # pad to chunk multiple
        return ResolvedConfig(self, dim, ns, n_cubes, n_cap, chunk)


@dataclasses.dataclass(frozen=True)
class ResolvedConfig:
    base: VegasConfig
    dim: int
    nstrat: int
    n_cubes: int
    n_cap: int
    chunk: int

    def __getattr__(self, name):
        return getattr(self.base, name)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class VegasState:
    """Everything the algorithm carries across iterations. O(KB): this is the
    checkpoint payload for fault-tolerant runs (DESIGN.md §5)."""
    edges: jax.Array      # (d, ninc+1) importance map
    n_h: jax.Array        # (n_cubes,) evals per hypercube
    key: jax.Array        # base PRNG key
    it: jax.Array         # iteration counter
    results: jax.Array    # (max_it, 2): per-iteration (I_i, sigma2_i)

    def tree_flatten(self):
        return (self.edges, self.n_h, self.key, self.it, self.results), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class VegasResult:
    mean: float
    sdev: float
    chi2_dof: float
    n_it: int
    iter_means: jax.Array
    iter_sdevs: jax.Array
    state: VegasState

    def __repr__(self):
        return (f"VegasResult(mean={self.mean:.8g}, sdev={self.sdev:.3g}, "
                f"chi2/dof={self.chi2_dof:.2f}, n_it={self.n_it})")


def init_state(integrand: Integrand, cfg: ResolvedConfig, key) -> VegasState:
    dtype = jnp.dtype(cfg.dtype)
    edges = vmap_.uniform_edges(integrand.lower, integrand.upper, cfg.ninc, dtype)
    n_h = strat.uniform_nh(cfg.neval, cfg.n_cubes)
    results = jnp.stack([jnp.zeros((cfg.max_it,), dtype),
                         jnp.full((cfg.max_it,), jnp.inf, dtype)], axis=1)
    return VegasState(edges, n_h, key, jnp.zeros((), jnp.int32), results)


def iteration_step(state: VegasState, integrand: Integrand,
                   cfg: ResolvedConfig, fill_fn=None) -> VegasState:
    """One VEGAS+ iteration. ``fill_fn`` lets dist/sharded_fill.py substitute
    the multi-device fill while reusing adaptation/aggregation unchanged."""
    dtype = jnp.dtype(cfg.dtype)
    key_it = jax.random.fold_in(state.key, state.it)
    if fill_fn is None:
        fill_fn = functools.partial(
            fill_mod.BACKENDS[cfg.backend], nstrat=cfg.nstrat, n_cap=cfg.n_cap,
            chunk=cfg.chunk, dtype=dtype,
            **({"interpret": cfg.interpret, "fused_cubes": cfg.fused_cubes,
                "tile": cfg.tile}
               if cfg.backend == "pallas" else {}))
    res = fill_fn(state.edges, state.n_h, key_it, integrand)

    i_it, sigma2_it, d_h = fill_mod.estimate_from_cubes(res, state.n_h)
    results = state.results.at[state.it].set(
        jnp.stack([i_it.astype(dtype), sigma2_it.astype(dtype)]))

    # Adaptive stratification (the "+" of VEGAS+); beta=0 freezes n_h uniform.
    n_h = (strat.adapt_nh(d_h, cfg.beta, cfg.neval)
           if cfg.beta > 0 else state.n_h)
    # Importance-map adaptation; alpha=0 freezes the map.
    edges = (vmap_.adapt_edges(state.edges, res.map_sums, res.map_counts, cfg.alpha)
             if cfg.alpha > 0 else state.edges)
    return VegasState(edges, n_h, state.key, state.it + 1, results)


def combine_results(results: jax.Array, skip: int, n_done: int):
    """Inverse-variance weighted combination across iterations (eq. (8)-(9))
    plus the chi^2/dof consistency diagnostic vegas reports.

    Degenerate case: when no iteration is usable (every sig2 is inf or
    non-finite, so ``wsum == 0``) the result is the NaN-free sentinel
    ``(0.0, inf, 0.0, 0)`` — zero information, not a silent NaN.
    """
    means, sig2 = results[:, 0], results[:, 1]
    idx = jnp.arange(results.shape[0])
    use = (idx >= skip) & (idx < n_done) & jnp.isfinite(sig2) & (sig2 > 0)
    wts = jnp.where(use, 1.0 / jnp.where(use, sig2, 1.0), 0.0)
    wsum = jnp.sum(wts)
    any_used = wsum > 0
    mean = jnp.where(any_used,
                     jnp.sum(wts * means) / jnp.where(any_used, wsum, 1.0), 0.0)
    var = 1.0 / wsum  # inf when nothing was usable (nan-free)
    n_used = jnp.sum(use)
    chi2 = jnp.sum(jnp.where(use, wts * (means - mean) ** 2, 0.0))
    chi2_dof = jnp.where(any_used, chi2 / jnp.maximum(n_used - 1, 1), 0.0)
    return mean, jnp.sqrt(var), chi2_dof, n_used


def run_loop(state: VegasState, integrand: Integrand, cfg: ResolvedConfig,
             start: int, fill_fn=None) -> VegasState:
    """The whole iteration loop as one traced program: ``lax.fori_loop`` over
    :func:`iteration_step` from ``start`` to ``cfg.max_it``.

    This is the jitted single-program path of ``run`` (no host sync between
    iterations, DESIGN.md B1) and the unit the batch engine ``vmap``s over
    scenarios (``repro.batch.engine``).  ``iteration_step`` keys its RNG and
    results slot off ``state.it``, so looping over it is bit-identical to
    stepping it from a host loop (checked by tests/test_determinism.py).
    """
    return jax.lax.fori_loop(
        start, cfg.max_it,
        lambda _, s: iteration_step(s, integrand, cfg, fill_fn), state)


def run(integrand: Integrand, cfg: VegasConfig | None = None, *,
        key=None, fill_fn=None, state: VegasState | None = None,
        checkpoint_cb: Callable[[int, VegasState], None] | None = None) -> VegasResult:
    """Run VEGAS+ to completion (or resume from ``state``).

    ``fill_fn(edges, n_h, key_it, integrand) -> FillResult`` overrides the
    configured backend — ``dist.sharded_fill.make_sharded_fill`` builds the
    multi-device one.  With no ``checkpoint_cb`` the whole loop executes as a
    single jitted on-device program (``run_loop``): zero host round-trips
    between iterations.  ``checkpoint_cb(it, state)`` switches to a host-side
    loop that invokes the callback after every iteration (the loop's only
    host sync; DESIGN.md §5.3) — pass ``lambda it, s: mgr.save(it, s)`` with
    a ``dist.checkpoint.CheckpointManager`` for fault tolerance; resume by
    passing the restored ``state`` (the results buffer grows automatically if
    the resuming config has a larger ``max_it``).
    """
    cfg = (cfg or VegasConfig()).resolve(integrand.dim)
    key = key if key is not None else jax.random.PRNGKey(0)
    if state is None:
        state = init_state(integrand, cfg, key)
    # The jitted step donates its input state; work on a copy so the caller's
    # key / checkpointed state stay alive (resume safety).
    state = jax.tree.map(jnp.copy, state)
    if state.results.shape[0] < cfg.max_it:
        # Resuming under a config with more iterations: grow the buffer.
        pad = cfg.max_it - state.results.shape[0]
        filler = jnp.stack([jnp.zeros((pad,), state.results.dtype),
                            jnp.full((pad,), jnp.inf, state.results.dtype)], 1)
        state = VegasState(state.edges, state.n_h, state.key, state.it,
                           jnp.concatenate([state.results, filler]))

    start = int(state.it)
    if checkpoint_cb is None:
        # On-device loop: one jitted program for the whole run.
        prog = jax.jit(functools.partial(
            run_loop, integrand=integrand, cfg=cfg, start=start,
            fill_fn=fill_fn), donate_argnums=0)
        state = prog(state)
    else:
        step = jax.jit(functools.partial(
            iteration_step, integrand=integrand, cfg=cfg, fill_fn=fill_fn),
            donate_argnums=0)
        for it in range(start, cfg.max_it):
            state = step(state)
            jax.block_until_ready(state.results)
            checkpoint_cb(it, state)

    mean, sdev, chi2_dof, n_used = combine_results(state.results, cfg.skip,
                                                   int(state.it))
    means, sig2 = state.results[:, 0], state.results[:, 1]
    return VegasResult(float(mean), float(sdev), float(chi2_dof), int(n_used),
                       means[: int(state.it)], jnp.sqrt(sig2[: int(state.it)]),
                       state)
