"""repro: multi-pod JAX framework reproducing cuVegas (VEGAS+ on TPU)."""
