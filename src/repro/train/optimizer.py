"""Optimizers: AdamW (ZeRO-ready — state shards wherever params shard) and
Adafactor (factored second moment, for the >=100B configs where Adam's m/v
would not fit the pod).  Functional: (init, update) pairs over pytrees."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]   # (grads, state, params) -> (params, state)


def adamw(lr=1e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          grad_clip=1.0) -> Optimizer:
    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": zeros,
                "v": jax.tree.map(jnp.zeros_like, zeros),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init, update)


def adafactor(lr=1e-4, decay_pow=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0) -> Optimizer:
    """Shazeer & Stern (2018), no momentum, factored v for >=2D params."""

    def init(params):
        def f(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"fac": jax.tree.map(f, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** -decay_pow

        def upd(p, g, f):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta2 * f["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * f["vc"] + (1 - beta2) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1)[..., None, None], eps))
                u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
                newf = {"vr": vr, "vc": vc}
            else:
                v = beta2 * f["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                newf = {"v": v}
            # update clipping (RMS(u) <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), newf

        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_f = treedef.flatten_up_to(state["fac"])
        outs = [upd(p, g, f) for p, g, f in zip(leaves_p, leaves_g, leaves_f)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_fac = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_params, {"fac": new_fac, "step": step}

    return Optimizer(init, update)


def for_config(cfg, lr=1e-4) -> Optimizer:
    return adafactor(lr=lr) if cfg.optimizer == "adafactor" else adamw(lr=lr)
