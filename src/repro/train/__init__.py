"""Training substrate: optimizers, data pipeline, train step."""
