"""Deterministic synthetic token pipeline.

Batches are a pure function of (seed, step): restart/elastic-safe in exactly
the same way as the vegas fill (DESIGN.md C5).  The token stream is a
Zipf-ish unigram mix with short-range structure so the LM loss has signal
(a pure-uniform stream cannot drop below log V).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def synthetic_batch(seed: int, step: int, *, batch: int, seq: int, vocab: int):
    """Returns dict(tokens (b, s) int32, labels (b, s) int32)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2 = jax.random.split(key)
    # Zipf-ish marginal via squaring a uniform (favors small ids)
    u = jax.random.uniform(k1, (batch, seq + 1))
    base = (u * u * vocab).astype(jnp.int32).clip(0, vocab - 1)
    # inject determinism: every 4th token repeats its predecessor (learnable)
    pos = jnp.arange(seq + 1)
    tokens = jnp.where((pos % 4 == 3)[None, :],
                       jnp.roll(base, 1, axis=1), base)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class DataLoader:
    """Step-indexed loader facade used by launch/train.py."""

    def __init__(self, seed: int, batch: int, seq: int, vocab: int):
        self.seed, self.batch, self.seq, self.vocab = seed, batch, seq, vocab

    def __call__(self, step: int):
        return synthetic_batch(self.seed, step, batch=self.batch,
                               seq=self.seq, vocab=self.vocab)
