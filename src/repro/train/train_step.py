"""Train step: LM loss + grad-accumulation microbatching + optimizer.

The microbatch loop is a lax.scan (sequential on device, grads averaged), so
per-step live activation memory is 1/n_micro of the full batch — the knob
that lets the 100B-1T configs fit HBM (config.microbatches_train_4k)."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.sharding import constrain


def lm_loss(params, batch, cfg, memory=None):
    logits = T.forward(params, batch["tokens"], cfg, memory=memory)
    logits = constrain(logits.astype(jnp.float32), "dp", None, "tp")
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                               axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_train_step(cfg, optimizer, n_micro: int = 1, mesh=None,
                    dp_axes=("data",), param_specs=None):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt"}; batch = {"tokens" (b, s), "labels" (b, s)
    [, "memory" (b, m, d)]}. b must divide by n_micro.

    ``mesh``/``dp_axes``: when given, the microbatch reshape is pinned to
    keep the micro axis UNSHARDED and the batch axis on the data axes —
    otherwise GSPMD may shard the micro axis and defeat grad accumulation.
    ``param_specs``: pinning each per-micro grad to its param's sharding
    turns the per-micro f32 grad ALL-REDUCE into a reduce-scatter into the
    (ZeRO-sharded) accumulator (§Perf A7).
    """

    def loss_fn(params, mb):
        return lm_loss(params, mb, cfg, memory=mb.get("memory"))

    def _pin(t):
        if mesh is None:
            return t
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = P(None, dp_axes, *([None] * (t.ndim - 2)))
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    def _pin_grads(g):
        if mesh is None or param_specs is None:
            return g
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.tree.map(
            lambda t, sp: jax.lax.with_sharding_constraint(
                t, NamedSharding(mesh, sp)),
            g, param_specs, is_leaf=lambda x: isinstance(x, P))

    def train_step(state, batch):
        params = state["params"]
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda t: _pin(
                    t.reshape((n_micro, t.shape[0] // n_micro) + t.shape[1:])),
                batch)

            def micro(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g = _pin_grads(g)
                return jax.tree.map(jnp.add, acc, (l.astype(jnp.float32), g)), None

            # accumulate each grad at its param's dtype: f32 models accumulate
            # in f32; bf16-param giants (>=398B) in bf16 — their f32
            # accumulator alone is 6+ GB/device (precision note in DESIGN.md)
            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(jnp.zeros_like, params))
            (loss, grads), _ = jax.lax.scan(micro, zero, mbs)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)

        new_params, new_opt = optimizer.update(grads, state["opt"], params)
        metrics = {"loss": loss}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_state(key, cfg, optimizer):
    params = T.init_params(key, cfg)
    return {"params": params, "opt": optimizer.init(params)}
