"""repro.engine: the unified execution-plan layer (DESIGN.md §9).

One pipeline — ``Plan -> Executor -> Result`` — composes the orthogonal
execution axes every run path shares:

  backend × batching × sharding × checkpointing × stopping

`make_plan` validates a (workload, VegasConfig, ExecutionConfig) combination
against the capability-declaring backend registry (`engine.backends`) and
fails fast with a `PlanError` for unsupported combinations; `execute` runs
the validated plan as one jitted program per run.  `core.run`,
`batch.run_batch`, and `dist.make_sharded_fill` are thin adapters over this
package.

Import structure note: `config` and `backends` load eagerly (they are
dependencies of `core.integrator`'s config shim and of `iteration_step`'s
default fill); `plan`/`executor`/`sharding` load lazily on first attribute
access because they import `core.integrator` back.
"""

from __future__ import annotations

import importlib

from .backends import (  # noqa: F401
    CAPABILITIES,
    BackendSpec,
    available,
    bind_fill,
    capability_matrix,
    register,
)
from .backends import get as get_backend  # noqa: F401
from .config import (  # noqa: F401
    BATCH_MODES,
    GRAD_MODES,
    CheckpointPolicy,
    ExecutionConfig,
    GradPolicy,
    PrecisionPolicy,
    StopPolicy,
)

_LAZY = {
    "Plan": "plan", "PlanError": "plan", "make_plan": "plan",
    "execute": "executor",
    "make_sharded_fill": "sharding", "make_local_fill": "sharding",
    "shard_chunk_range": "sharding", "mesh_shard_count": "sharding",
    "replicated_shard_map": "sharding", "make_stop_sync": "sharding",
    "CostTable": "autotune", "TuneReport": "autotune",
    "calibrate": "autotune", "resolve_table": "autotune",
    "plan": "plan", "executor": "executor", "sharding": "sharding",
    "autotune": "autotune",
}

__all__ = [
    "BATCH_MODES", "BackendSpec", "CAPABILITIES", "CheckpointPolicy",
    "CostTable", "ExecutionConfig", "GRAD_MODES", "GradPolicy", "Plan",
    "PlanError", "PrecisionPolicy", "StopPolicy", "TuneReport", "available",
    "bind_fill",
    "calibrate", "capability_matrix", "execute", "get_backend", "make_plan",
    "make_sharded_fill", "make_stop_sync", "register", "resolve_table",
]


def __getattr__(name):
    modname = _LAZY.get(name)
    if modname is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    mod = importlib.import_module(f".{modname}", __name__)
    return mod if name == modname else getattr(mod, name)
