"""Execution configuration: HOW a VEGAS+ run executes, split from WHAT it
computes.

`core.integrator.VegasConfig` carries the algorithm parameters (neval, ninc,
alpha, beta, ... — the paper's Table 2 names); :class:`ExecutionConfig`
carries the orthogonal execution axes the engine composes
(DESIGN.md §9, §10):

  * **backend**  — which fill implementation (`engine.backends` registry:
                   ``ref`` / ``pallas`` / ``pallas-fused`` / ``pallas-gpu``,
                   or ``auto`` for the platform default) plus its knobs
                   (``interpret``, ``tile``, ``block``, ``num_warps``);
  * **batching** — how an `IntegrandFamily` workload executes (``vmap`` over
                   the scenario axis vs a ``serial`` per-scenario loop);
  * **sharding** — a device mesh + axis names to shard the fill's global
                   chunk axis over (`engine.sharding`);
  * **checkpointing** — a :class:`CheckpointPolicy` that switches the run to
                   the host-side loop and persists `VegasState` every
                   iteration (`dist.checkpoint`);
  * **stopping**  — a :class:`StopPolicy` convergence target (rtol/atol/
                   min_it) that turns the fixed ``fori_loop`` into an
                   adaptive fixed-shape ``lax.while_loop`` (DESIGN.md §10);
  * **autotuning** — ``autotune=True`` asks ``make_plan`` to pick the
                   chunk/tile/batch-split/shard knobs from the measured
                   per-device cost model (`engine.autotune`, DESIGN.md §13;
                   ``cost_table`` overrides the table lookup);
  * **gradients** — a :class:`GradPolicy` that makes the run differentiable
                   (`repro.grad`, DESIGN.md §11): adapt with gradients
                   stopped, then a frozen-map evaluation pass whose pathwise
                   (or score-function) Monte Carlo gradient flows to
                   integrand parameters and integration bounds.

The split exists so that every run path — single scenario, batched family,
sharded fill, and their combinations — consumes ONE config object instead of
re-threading backend flags by hand (the config sprawl this replaces).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

#: Legacy flat `VegasConfig` fields that now live on ExecutionConfig.
LEGACY_EXEC_FIELDS = ("backend", "interpret", "fused_cubes", "tile")

#: Valid values of ExecutionConfig.batch.
BATCH_MODES = ("auto", "vmap", "serial")

#: Valid values of GradPolicy.mode ("off" normalizes to no policy at plan
#: time, mirroring the inert-StopPolicy convention).
GRAD_MODES = ("pathwise", "score", "off")


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Per-plan numeric precision policy (DESIGN.md §15).

    ``sample_dtype`` is the dtype samples, transforms, and integrand products
    are computed in; ``accum_dtype`` is the dtype the fill's moment
    accumulators (map histogram, per-cube s1/s2) carry.  Either may be None:
    ``sample_dtype=None`` inherits the algorithm config's own ``dtype`` and
    ``accum_dtype=None`` matches the sample dtype (the classic single-dtype
    run).  The interesting split is ``f32 -> f64``: products stay f32 — on
    the fused TPU kernel they must, the MXU contracts f32 and the in-kernel
    RNG reproduces the f32 uniform bit pattern — but every running sum is
    widened to f64 before accumulation, cuVegas' own double-precision
    accumulator design.

    ``make_plan`` validates the resolved ``(sample, accum)`` pair against
    the backend registry's declared capability pairs
    (`BackendSpec.precisions`) and rejects unsupported combinations with a
    one-line PlanError — e.g. ``f64`` samples on a fused backend (the RNG
    contract is f32-only) or a widened accumulator without x64 enabled.
    """
    sample_dtype: str | None = None   # None = inherit VegasConfig.dtype
    accum_dtype: str | None = None    # None = same as sample_dtype

    @property
    def widened(self) -> bool:
        """True when accumulation runs wider than sampling (the policy does
        something beyond the classic single-dtype run)."""
        if self.accum_dtype is None:
            return False
        import numpy as np
        return (np.dtype(self.accum_dtype).itemsize
                > np.dtype(self.sample_dtype or "float32").itemsize)

    def describe(self) -> str:
        s = self.sample_dtype or "cfg"
        a = self.accum_dtype or s
        return f"{s}->{a}"


@dataclasses.dataclass(frozen=True)
class GradPolicy:
    """Differentiable-integration policy (DESIGN.md §11, `repro.grad`).

    A run under an active policy executes in two phases: the adaptive loop
    runs with every gradient stopped (map and stratification evolution are
    ``stop_gradient``-frozen), then ONE frozen-map evaluation pass produces
    the returned estimate.  For a fixed map the estimator is unbiased
    whatever the map, so dropping the adaptation's parameter-dependence is
    unbiased for the frozen-map estimate — and the eval pass's gradient is
    an exact Monte Carlo estimator of ``dI/dtheta``.

    ``mode`` selects the estimator the backward pass evaluates:

      * ``pathwise`` — the reparameterized gradient ``E[J(y) df/dtheta]``:
        samples are a fixed function of (frozen map, chunk-keyed uniforms),
        so differentiating the integrand along each sample path is exact.
      * ``score``    — the log-derivative form ``E[J f d(log f)/dtheta]``:
        equal to pathwise wherever ``f > 0`` (``f dlog f = df``) but needing
        only the score of the integrand — the form available when ``f`` is
        computed in log-space (Bayesian-evidence workloads); samples with
        ``f <= 0`` contribute zero gradient.
      * ``off``      — inert; `make_plan` normalizes the policy to ``None``.

    ``with_sdev`` asks terminal runners (the executor / CLIs) to also
    estimate each gradient component's own Monte Carlo uncertainty by
    integrating the derivative integrand through the same frozen-map pass
    (one extra fill per parameter component).
    """
    mode: str = "pathwise"
    with_sdev: bool = True

    @property
    def active(self) -> bool:
        return self.mode != "off"

    def describe(self) -> str:
        bits = [self.mode]
        if self.with_sdev:
            bits.append("with_sdev")
        return ",".join(bits)


@dataclasses.dataclass(frozen=True)
class StopPolicy:
    """Convergence target for the adaptive iteration loop (DESIGN.md §10).

    A run stops once its inverse-variance combined estimate satisfies the
    vegas package's criterion ``sdev <= max(rtol * |mean|, atol)`` AND at
    least ``min_it`` iterations have executed.  With both tolerances at 0
    the policy is inert (``make_plan`` normalizes it to ``None`` and the
    fixed-length ``fori_loop`` runs).

    The loop stays a fixed-shape ``lax.while_loop`` — the results buffer is
    always ``(max_it, 2)`` and unfilled slots keep the ``sigma2 = inf``
    sentinel — so a stop-policy program is jittable, vmappable (per-scenario
    stop masks come from the while_loop batching rule), and resumes from
    the same checkpoints as a fixed run.  ``skip`` iterations never enter
    the combination, so the loop cannot stop before ``skip + 1`` iterations
    regardless of ``min_it`` (the combined sdev is still ``inf`` there).
    """
    rtol: float = 0.0
    atol: float = 0.0
    min_it: int = 2

    @property
    def active(self) -> bool:
        return self.rtol > 0.0 or self.atol > 0.0

    def converged(self, mean, sdev, n_done):
        """Traced convergence predicate on the running combined stats."""
        import jax.numpy as jnp
        target = jnp.maximum(self.rtol * jnp.abs(mean), self.atol)
        return (n_done >= self.min_it) & (sdev <= target)

    def describe(self) -> str:
        bits = []
        if self.rtol > 0:
            bits.append(f"rtol={self.rtol:g}")
        if self.atol > 0:
            bits.append(f"atol={self.atol:g}")
        bits.append(f"min_it={self.min_it}")
        return ",".join(bits)


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """When and where to persist `VegasState` during a run.

    Any policy forces the host-side iteration loop (checkpointing is
    inherently a host sync, DESIGN.md §5.3).  Either give a ``directory``
    (a `dist.checkpoint.CheckpointManager` is built with ``keep`` retention)
    or a ``callback(it, state)`` of your own; ``every`` throttles how often
    the save fires (the host loop still runs every iteration).
    """
    directory: str | None = None
    keep: int = 3
    every: int = 1
    callback: Callable[[int, Any], None] | None = None

    def build_callback(self) -> Callable[[int, Any], None]:
        base = self.callback
        if base is None:
            if self.directory is None:
                raise ValueError(
                    "CheckpointPolicy needs a directory or a callback")
            from repro.dist.checkpoint import CheckpointManager
            mgr = CheckpointManager(self.directory, keep=self.keep)
            base = lambda it, state: mgr.save(it, state)
        if self.every <= 1:
            return base
        every = self.every

        def throttled(it, state):
            if (it + 1) % every == 0:
                base(it, state)
        return throttled


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """The execution axes, as data.  Validation happens at plan time
    (`engine.plan.make_plan`), not here — so configs stay cheap to build and
    the error surfaces exactly once, with the full workload context."""
    backend: str = "ref"            # engine.backends registry name, or
                                    # 'auto' = platform default
                                    # (kernels.backend_default: pallas-fused
                                    # on TPU, pallas-gpu on GPU, ref on CPU)
    interpret: bool | None = None   # pallas mode; None = platform autodetect
    tile: int | None = None         # pallas tile; None = VMEM autotune
    block: int | None = None        # pallas-gpu evals per program; None =
                                    # shared-memory autotune (gpu_fill)
    num_warps: int | None = None    # pallas-gpu Triton compiler knob
    batch: str = "auto"             # family execution: auto | vmap | serial
    mesh: Any = None                # jax Mesh; None = unsharded
    shard_axes: tuple[str, ...] | None = None  # mesh axes to shard fill over
    checkpoint: CheckpointPolicy | None = None
    stop: StopPolicy | None = None  # convergence target -> while_loop (§10)
    grad: GradPolicy | None = None  # differentiable two-phase run (§11)
    precision: PrecisionPolicy | None = None  # sample/accum dtype pair (§15):
                                    # None = single-dtype run in cfg.dtype;
                                    # PrecisionPolicy(accum_dtype='float64')
                                    # widens the moment accumulators
    autotune: bool = False          # measured-cost-model knob choice (§13):
                                    # make_plan picks chunk/tile/batch/shard
                                    # via engine.autotune.tune
    cost_table: Any = None          # autotune table override: a CostTable or
                                    # a path; None = resolve_table order

    def with_legacy(self, **flat) -> "ExecutionConfig":
        """Fold the pre-engine flat `VegasConfig` fields (``backend``,
        ``interpret``, ``fused_cubes``, ``tile``) into this config — the
        deprecation shim `VegasConfig.__init__` applies.

        Legacy ``backend='pallas'`` meant the *fused* kernel unless
        ``fused_cubes=False`` was also passed; the registry names the two
        paths explicitly (``pallas-fused`` vs ``pallas``).
        """
        unknown = set(flat) - set(LEGACY_EXEC_FIELDS)
        if unknown:
            raise TypeError(f"unknown execution fields: {sorted(unknown)}")
        backend = flat.get("backend", self.backend)
        # The remap applies only when a legacy backend/fused_cubes kwarg was
        # actually given — an explicitly chosen registry name (e.g.
        # ExecutionConfig(backend='pallas') for P-V2) must never be upgraded
        # just because some other legacy kwarg (interpret/tile) rode along.
        if "backend" in flat or "fused_cubes" in flat:
            default_fused = ("backend" in flat
                             or self.backend == "pallas-fused")
            fused = flat.get("fused_cubes", default_fused)
            if backend in ("pallas", "pallas-fused"):
                backend = "pallas-fused" if fused else "pallas"
        kw = {k: flat[k] for k in ("interpret", "tile") if k in flat}
        return dataclasses.replace(self, backend=backend, **kw)

    def describe(self) -> str:
        bits = [f"backend={self.backend}"]
        if self.interpret is not None:
            bits.append(f"interpret={self.interpret}")
        if self.tile is not None:
            bits.append(f"tile={self.tile}")
        if self.block is not None:
            bits.append(f"block={self.block}")
        if self.num_warps is not None:
            bits.append(f"num_warps={self.num_warps}")
        if self.batch != "auto":
            bits.append(f"batch={self.batch}")
        if self.mesh is not None:
            axes = self.shard_axes or tuple(self.mesh.axis_names)
            shape = "x".join(str(self.mesh.shape[a]) for a in axes)
            bits.append(f"shard={shape}@{','.join(axes)}")
        if self.checkpoint is not None:
            bits.append("checkpoint=on")
        if self.stop is not None and self.stop.active:
            bits.append(f"stop[{self.stop.describe()}]")
        if self.grad is not None and self.grad.active:
            bits.append(f"grad[{self.grad.describe()}]")
        if self.precision is not None:
            bits.append(f"precision[{self.precision.describe()}]")
        if self.autotune:
            bits.append("autotune")
        return " ".join(bits)
