"""Executor: run a validated :class:`~repro.engine.plan.Plan`.

One entry point, :func:`execute`, composes the plan axes into a single
program per run:

  * **single scenario** — `core.run_loop` as one jitted ``fori_loop``
    program (or the host loop when a checkpoint policy is set);
  * **batched family**  — the whole loop ``vmap``ped over the scenario axis
    (`repro.batch` semantics: scenario ``b`` streams from ``fold_in(key,
    b)``, so batched == serial stream-for-stream);
  * **sharded**         — the fill's chunk axis divided over the mesh.  For
    single runs the fill call is shard_mapped; for batched runs the ENTIRE
    vmapped program runs inside one ``shard_map`` with the per-shard fill +
    psum inline — B integrands × D devices as ONE jitted XLA program, the
    combination the pre-engine run paths could not express;
  * **checkpointing**   — the policy's callback after every iteration on the
    host-loop path, composing with sharding (mesh-free payload, §5);
  * **stopping**        — an active `StopPolicy` swaps the fori_loop for the
    fixed-shape while_loop (§10): single runs stop when the combined sdev
    target is met, batched runs carry per-scenario stop masks, and the
    sharded batched program pmin-agrees the decision across the mesh
    (`sharding.make_stop_sync`).

`core.run` and `batch.run_batch` are thin adapters over this module.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.batch.engine import BatchResult, scenario_keys
from repro.core import integrator as core
from repro.core import map as vmap_

from . import backends as backends_mod
from . import sharding as sharding_mod
from .plan import Plan


def execute(plan: Plan, *, key=None, state: core.VegasState | None = None,
            cache=None, fill_fn=None, checkpoint_cb=None, keys=None,
            it_caps=None, edges0=None):
    """Run a plan.

    ``key`` defaults to ``PRNGKey(0)``.  ``state`` resumes a single-scenario
    run from a checkpoint; ``cache`` warm-starts a family run's importance
    maps (`batch.cache.MapCache`).  ``fill_fn`` overrides the plan's entire
    backend/sharding wiring with a custom ``fill_fn(edges, n_h, key,
    integrand)`` — the legacy `core.run` extension hook `repro.dist` built
    on; prefer expressing sharding through the plan.  ``checkpoint_cb``
    overrides the plan's checkpoint policy callback.

    Serving hooks (§12, used by `repro.serve`):

      * ``keys`` — explicit per-scenario base keys ``(B, ...)`` for a
        batched family plan, replacing the default ``fold_in(key, b)``
        derivation (`batch.engine.scenario_keys`).  A coalesced micro-batch
        keeps every request's own stream this way, so results are invariant
        to how requests were batched together.
      * ``it_caps`` — the time-budget stopping input: an iteration-count
        cap (scalar for single runs, per-scenario ``(B,)`` for batched
        runs) threaded into the while_loop carry (`core.run_loop`).
      * ``edges0`` — explicit warm-start importance maps ``(B, d, ninc+1)``
        for a batched family plan (mutually exclusive with ``cache``; the
        serving layer pools maps across batch sizes itself).

    Returns `VegasResult` (single scenario), `BatchResult` (vmapped family),
    or ``list[VegasResult]`` (``batch='serial'`` family).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    if plan.grad is not None:
        # §11 route: the two-phase differentiable program (repro.grad).  It
        # is one traced program per run — none of the imperative hooks
        # (resume state, warm-start cache, fill/checkpoint overrides)
        # compose with a custom-AD boundary.
        if (state is not None or cache is not None or fill_fn is not None
                or checkpoint_cb is not None or keys is not None
                or it_caps is not None or edges0 is not None):
            raise ValueError(
                "a grad plan takes no state/cache/fill_fn/checkpoint_cb/"
                "keys/it_caps/edges0 hooks; drop the GradPolicy or the hook")
        from repro.grad.api import execute_grad
        return execute_grad(plan, key)
    if plan.is_family:
        if state is not None:
            raise ValueError("state resume is a single-scenario feature; "
                             "family runs restart from the map cache instead")
        if fill_fn is not None or checkpoint_cb is not None:
            raise ValueError(
                "fill_fn/checkpoint_cb are single-scenario hooks; express "
                "sharding and checkpointing for family runs through "
                "ExecutionConfig (mesh=..., checkpoint=...)")
        if plan.batched:
            if cache is not None and edges0 is not None:
                raise ValueError("cache and edges0 are two spellings of the "
                                 "same warm start — pass one")
            return _execute_family_vmap(plan, key, cache, keys=keys,
                                        it_caps=it_caps, edges0=edges0)
        if cache is not None or keys is not None or edges0 is not None:
            raise ValueError("cache/keys/edges0 apply to the vmapped "
                             "batch program; this plan resolved to "
                             "batch='serial'")
        return _execute_family_serial(plan, key, it_caps=it_caps)
    if cache is not None or keys is not None or edges0 is not None:
        raise ValueError("cache/keys/edges0 are family features; "
                         "single-scenario runs resume from state instead")
    return _execute_single(plan, key, state, fill_fn, checkpoint_cb,
                           it_cap=it_caps)


# --- single scenario ---------------------------------------------------------

def _plan_fill_fn(plan: Plan, *, local: bool = False):
    """The plan's fill: registry-bound, shard_mapped when the plan shards.
    ``local=True`` returns the inside-shard_map form (batched program)."""
    if plan.n_shards > 1:
        if local:
            return sharding_mod.make_local_fill(
                plan.cfg, plan.mesh, plan.shard_axes,
                backend=plan.backend.name)
        return sharding_mod.make_sharded_fill(
            plan.mesh, plan.shard_axes, plan.cfg, backend=plan.backend.name)
    return backends_mod.bind_fill(plan.cfg, backend=plan.backend.name)


def _execute_single(plan: Plan, key, state, fill_fn, checkpoint_cb,
                    it_cap=None):
    cfg, integrand = plan.cfg, plan.workload
    if it_cap is not None and jnp.ndim(it_cap) != 0:
        raise ValueError(
            f"a single-scenario run takes a scalar it_cap, got shape "
            f"{jnp.shape(it_cap)} (per-scenario caps are a batched-family "
            f"feature)")
    if fill_fn is None:
        fill_fn = _plan_fill_fn(plan)
    if checkpoint_cb is None and plan.checkpoint is not None:
        checkpoint_cb = plan.checkpoint.build_callback()
    if checkpoint_cb is not None and plan.stop is not None:
        # Same conflict make_plan rejects for the plan-level policy: the
        # legacy hook forces the host loop, the stop policy is the on-device
        # while_loop.  One implementation of the stop semantics, not two.
        raise ValueError(
            "checkpoint_cb forces the host loop and cannot combine with a "
            "StopPolicy (the on-device while_loop); checkpoint with a fixed "
            "loop, then resume the saved state under the stop policy")

    if state is None:
        state = core.init_state(integrand, cfg, key)
    # The jitted step donates its input state; work on a copy so the caller's
    # key / checkpointed state stay alive (resume safety).
    state = jax.tree.map(jnp.copy, state)
    if state.results.shape[0] < cfg.max_it:
        # Resuming under a config with more iterations: grow the buffer.
        pad = cfg.max_it - state.results.shape[0]
        filler = jnp.stack([jnp.zeros((pad,), state.results.dtype),
                            jnp.full((pad,), jnp.inf, state.results.dtype)], 1)
        state = core.VegasState(state.edges, state.n_h, state.key, state.it,
                                jnp.concatenate([state.results, filler]))

    start = int(state.it)
    if checkpoint_cb is None:
        # On-device loop: one jitted program for the whole run (fori_loop,
        # or the stop policy's / iteration cap's fixed-shape while_loop).
        prog = jax.jit(functools.partial(
            core.run_loop, integrand=integrand, cfg=cfg, start=start,
            fill_fn=fill_fn, stop=plan.stop), donate_argnums=0)
        kw = ({} if it_cap is None
              else {"it_cap": jnp.asarray(it_cap, jnp.int32)})
        state = prog(state, **kw)
    else:
        step = jax.jit(functools.partial(
            core.iteration_step, integrand=integrand, cfg=cfg,
            fill_fn=fill_fn), donate_argnums=0)
        end = cfg.max_it if it_cap is None else min(cfg.max_it, int(it_cap))
        for it in range(start, end):
            state = step(state)
            jax.block_until_ready(state.results)
            checkpoint_cb(it, state)

    n_it_used = int(state.it)
    mean, sdev, chi2_dof, n_used = core.combine_results(
        state.results, cfg.skip, n_it_used)
    means, sig2 = state.results[:, 0], state.results[:, 1]
    return core.VegasResult(float(mean), float(sdev), float(chi2_dof),
                            int(n_used), means[:n_it_used],
                            jnp.sqrt(sig2[:n_it_used]), state,
                            n_it_used=n_it_used)


# --- batched family ----------------------------------------------------------

def uniform_family_edges(family, cfg, b: int):
    """The cold-start importance maps: the family's uniform map broadcast
    over the scenario axis ``(b, d, ninc+1)``."""
    uni = vmap_.uniform_edges(family.lower, family.upper, cfg.ninc,
                              jnp.dtype(cfg.dtype))
    return jnp.broadcast_to(uni, (b,) + uni.shape)


def make_single_program(plan: Plan):
    """Build the jitted whole-run program of a single-scenario plan ONCE,
    for callers that run the same plan repeatedly — ``prog(state) ->
    state``.  Unlike the per-call program inside :func:`execute` it does not
    donate its input, so one initial state can be replayed; steady-state
    timing (``benchmarks/bench_runs.py``, `engine.autotune.calibrate`)
    needs exactly this — the knob effects the cost model fits are several
    times smaller than trace+compile, which a fresh-jit-per-call timing
    would re-pay and drown in."""
    if plan.is_family or plan.checkpoint is not None:
        raise ValueError("make_single_program builds the single-scenario "
                         "on-device loop; use make_family_program for "
                         "batched plans")
    fill_fn = _plan_fill_fn(plan)
    return jax.jit(functools.partial(
        core.run_loop, integrand=plan.workload, cfg=plan.cfg, start=0,
        fill_fn=fill_fn, stop=plan.stop))


def make_family_program(plan: Plan, *, with_caps: bool = False):
    """Build the jitted vmapped whole-run program of a batched family plan.

    Returns ``prog(params, keys, edges0[, it_caps]) -> (states, mean, sdev,
    chi2_dof, n_used)`` with every per-scenario input carried on axis 0.
    The callable is shape-polymorphic over the batch size (jit retraces per
    B), so a long-lived caller — the serving layer's micro-batcher (§12) —
    caches ONE program per compatibility class and reuses it across bursts
    instead of paying trace+compile on every batch.  ``with_caps=True``
    threads a per-scenario iteration cap ``(B,)`` into the while_loop carry
    (the time-budget stopping input, `core.run_loop`).
    """
    family, cfg = plan.workload, plan.cfg
    fill_fn = _plan_fill_fn(plan, local=True)
    # Per-scenario stop masks come from vmapping the while_loop itself
    # (converged lanes keep their old carry); under the sharded batched
    # program the continue decision is additionally pmin-agreed across the
    # mesh so all shards run the same trip count (§10).
    stop_sync = (sharding_mod.make_stop_sync(plan.shard_axes)
                 if plan.stop is not None and plan.n_shards > 1 else None)

    def one(params, key_b, edges0_b, cap_b=None):
        ig = family.bind(params)
        st = core.init_state(ig, cfg, key_b)
        st = core.VegasState(edges0_b, st.n_h, st.key, st.it, st.results)
        st = core.run_loop(st, ig, cfg, 0, fill_fn=fill_fn, stop=plan.stop,
                           stop_sync=stop_sync, it_cap=cap_b)
        mean, sdev, chi2_dof, n_used = core.combine_results(
            st.results, cfg.skip, st.it)
        return st, mean, sdev, chi2_dof, n_used

    n_args = 4 if with_caps else 3
    batched = jax.vmap(one if with_caps
                       else lambda p, k, e: one(p, k, e))
    if plan.n_shards > 1:
        # ONE shard_map around the ENTIRE vmapped run: the per-shard fill +
        # psum runs inside the scenario vmap, every device carries the full
        # replicated O(B·KB) adaptation state, and the fill's chunk axis is
        # divided per scenario.  B integrands × D devices, one XLA program.
        batched = sharding_mod.replicated_shard_map(batched, plan.mesh,
                                                    n_args)
    return jax.jit(batched)


def package_batch_result(states, mean, sdev, chi2_dof, n_used, *,
                         warm_started: bool = False) -> BatchResult:
    """Package a family program's device outputs into a `BatchResult`.

    iter_sdevs keeps the buffer's inf sentinels past each scenario's
    n_it_used slot — consumers filter on n_it_used (combine_results
    already did, per scenario, via its n_done mask).
    """
    sig2 = np.asarray(states.results[:, :, 1])
    return BatchResult(np.asarray(mean), np.asarray(sdev),
                       np.asarray(chi2_dof), np.asarray(n_used),
                       np.asarray(states.it, dtype=np.int64),
                       np.asarray(states.results[:, :, 0]), np.sqrt(sig2),
                       states, warm_started=warm_started)


def _execute_family_vmap(plan: Plan, key, cache, *, keys=None, it_caps=None,
                         edges0=None):
    family, cfg = plan.workload, plan.cfg
    b = plan.batch_size

    if edges0 is None and cache is not None:
        edges0 = cache.get(family, cfg)
    warm = edges0 is not None
    if edges0 is None:
        edges0 = uniform_family_edges(family, cfg, b)
    edges0 = jnp.asarray(edges0)
    if edges0.shape[0] != b:
        raise ValueError(f"edges0 carries {edges0.shape[0]} scenarios, the "
                         f"plan has {b}")

    if keys is None:
        keys = scenario_keys(key, b)
    elif jnp.shape(keys)[0] != b:
        raise ValueError(f"keys carries {jnp.shape(keys)[0]} scenarios, the "
                         f"plan has {b}")

    args = [family.params, keys, edges0]
    if it_caps is not None:
        caps = jnp.asarray(it_caps, jnp.int32)
        if caps.ndim == 0:
            caps = jnp.full((b,), caps, jnp.int32)
        elif caps.shape != (b,):
            raise ValueError(f"it_caps shape {caps.shape} != ({b},)")
        args.append(caps)

    prog = make_family_program(plan, with_caps=it_caps is not None)
    states, mean, sdev, chi2_dof, n_used = prog(*args)

    if cache is not None:
        cache.put(family, cfg, states.edges)
    return package_batch_result(states, mean, sdev, chi2_dof, n_used,
                                warm_started=warm)


def _execute_family_serial(plan: Plan, key, it_caps=None):
    """The B scenarios as B independent single-scenario executions (the
    baseline the vmapped program is measured against; same per-scenario
    keys, so the streams match the batched run exactly)."""
    family = plan.workload
    out = []
    for b in range(family.batch_size):
        single = dataclasses.replace(plan, workload=family.instance(b),
                                     is_family=False, batched=False,
                                     batch_size=1)
        cap = (None if it_caps is None else
               np.broadcast_to(np.asarray(it_caps), (family.batch_size,))[b])
        out.append(_execute_single(single, jax.random.fold_in(key, b),
                                   None, None, None, it_cap=cap))
    return out
