"""Capability-declaring fill-backend registry.

Every fill implementation registers a :class:`BackendSpec` here: the callable
(one shared contract, ``fill(edges, n_h, key, integrand, *, nstrat, n_cap,
chunk, dtype, start_chunk, n_chunks, kahan, **knobs) -> FillResult``), the
**capabilities** it declares, the ExecutionConfig **knobs** it accepts, and
the accumulation dtypes it supports.  Plan validation
(`engine.plan.make_plan`) reads the declarations and rejects unsupported
backend × axis combinations loudly at plan time — instead of the historical
failure mode, an opaque tracer error from deep inside `shard_map`/`vmap`.

Capabilities (DESIGN.md §9 capability matrix):

  * ``shardable``        — honors ``start_chunk``/``n_chunks`` + ``kahan``
                           under ``shard_map`` (the C5 chunk contract);
  * ``vmappable``        — traces correctly under ``jax.vmap`` over an
                           `IntegrandFamily`'s parameter axis;
  * ``in-kernel-rng``    — regenerates its uniforms inside the kernel
                           (no per-eval RNG traffic when compiled, P-V3);
  * ``closure-hoisting`` — accepts integrands that close over arrays
                           (ridge's peak table, vmapped family params);
  * ``early-stop``       — traces correctly inside the adaptive
                           ``lax.while_loop`` body (`StopPolicy` runs, §10):
                           no iteration-index specialization, no host
                           callbacks inside the fill;
  * ``grad-pathwise``    — can anchor the differentiable two-phase run
                           (`GradPolicy(mode='pathwise')`, §11): the eval
                           pass's value may come from this backend while the
                           cotangent is evaluated through the reference
                           formulation on the SAME chunk-keyed stream (the
                           bit-exact RNG contract is what makes the pairing
                           coherent).  ``pallas-fused`` cannot declare it:
                           with the RNG regenerated inside the kernel and
                           moments accumulated in VMEM there is no JAX-level
                           sample path left to pair a VJP against;
  * ``grad-score``       — supports the score-function gradient fallback
                           (`GradPolicy(mode='score')`): the surrogate
                           rewrites the integrand sample-by-sample, which
                           needs the reference (pure-jnp) eval path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping

from repro.core import fill as fill_mod

SHARDABLE = "shardable"
VMAPPABLE = "vmappable"
IN_KERNEL_RNG = "in-kernel-rng"
CLOSURE_HOISTING = "closure-hoisting"
EARLY_STOP = "early-stop"
GRAD_PATHWISE = "grad-pathwise"
GRAD_SCORE = "grad-score"

CAPABILITIES = (SHARDABLE, VMAPPABLE, IN_KERNEL_RNG, CLOSURE_HOISTING,
                EARLY_STOP, GRAD_PATHWISE, GRAD_SCORE)


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One registered fill implementation + its declared envelope."""
    name: str
    fill: Callable[..., Any]
    capabilities: frozenset
    knobs: tuple[str, ...] = ()       # ExecutionConfig fields forwarded as kwargs
    fixed: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    #: Declared (sample_dtype, accum_dtype) capability pairs (DESIGN.md §15):
    #: which PrecisionPolicy combinations this fill implements.  Samples on
    #: the kernel backends are pinned to f32 by the in-kernel RNG contract
    #: (the threefry mantissa trick reproduces the f32 uniform bit pattern);
    #: widened f32->f64 accumulation is a separate, declarable capability.
    precisions: tuple[tuple[str, str], ...] = (("float32", "float32"),)
    family: str = "tpu"               # platform that compiles this kernel
                                      # natively (kernels.resolve_interpret);
                                      # irrelevant without an 'interpret' knob
    doc: str = ""

    def supports(self, capability: str) -> bool:
        return capability in self.capabilities

    @property
    def dtypes(self) -> tuple[str, ...]:
        """Accepted SAMPLE dtypes, derived from the precision pairs (the
        pre-§15 single-axis declaration, kept for messages and callers)."""
        return tuple(dict.fromkeys(s for s, _ in self.precisions))


_REGISTRY: dict[str, BackendSpec] = {}


def register(spec: BackendSpec, *, overwrite: bool = False) -> BackendSpec:
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {spec.name!r} already registered")
    bad = set(spec.capabilities) - set(CAPABILITIES)
    if bad:
        raise ValueError(f"unknown capabilities {sorted(bad)}; "
                         f"known: {CAPABILITIES}")
    _REGISTRY[spec.name] = spec
    return spec


def available() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get(name: str) -> BackendSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown fill backend {name!r}; registered: {available()}"
        ) from None


def bind_fill(rcfg, *, backend: str | None = None, **overrides) -> Callable:
    """Bind a registered backend to a resolved config.

    Returns ``fill(edges, n_h, key, integrand, **runtime)`` with the
    geometry (``nstrat``/``n_cap``/``chunk``/``dtype``), the spec's pinned
    kwargs, and the backend's declared ExecutionConfig knobs already applied.
    ``overrides`` (e.g. ``kahan=True`` for sharded partials) win last.
    This is the single replacement for the old ``fill_mod.BACKENDS`` dict +
    the per-call-site kwargs threading.
    """
    import jax.numpy as jnp

    spec = get(backend if backend is not None else rcfg.execution.backend)
    kw = dict(nstrat=rcfg.nstrat, n_cap=rcfg.n_cap, chunk=rcfg.chunk,
              dtype=jnp.dtype(rcfg.dtype))
    prec = getattr(rcfg.execution, "precision", None)
    if prec is not None and prec.accum_dtype is not None:
        # The §15 accumulation dtype rides the same binding point as the
        # sample dtype, so every fill call site (executor, sharding, serve,
        # autotune probes) inherits the policy without new threading.
        kw["accum_dtype"] = jnp.dtype(prec.accum_dtype)
    kw.update(spec.fixed)
    for knob in spec.knobs:
        kw[knob] = getattr(rcfg.execution, knob)
    kw.update(overrides)
    return functools.partial(spec.fill, **kw)


def capability_matrix() -> str:
    """Human-readable capability table (the `--plan` CLI output and
    DESIGN.md §9 render this)."""
    lines = ["backend          " + "  ".join(f"{c:<16}" for c in CAPABILITIES)]
    for name in available():
        spec = _REGISTRY[name]
        row = "  ".join(f"{'yes' if spec.supports(c) else '-':<16}"
                       for c in CAPABILITIES)
        lines.append(f"{name:<17}{row}")
    return "\n".join(lines)


# --- the built-in backends ---------------------------------------------------

register(BackendSpec(
    name="ref",
    fill=fill_mod.fill_reference,
    capabilities=frozenset({SHARDABLE, VMAPPABLE, CLOSURE_HOISTING,
                            EARLY_STOP, GRAD_PATHWISE, GRAD_SCORE}),
    knobs=(),
    precisions=(("float32", "float32"), ("float32", "float64"),
                ("float64", "float64"), ("float64", "float32")),
    doc="pure-jnp oracle: scatter-add accumulation, chunked lax.scan",
))

register(BackendSpec(
    name="pallas",
    fill=fill_mod.fill_pallas,
    capabilities=frozenset({SHARDABLE, VMAPPABLE, CLOSURE_HOISTING,
                            EARLY_STOP, GRAD_PATHWISE}),
    knobs=("interpret", "tile"),
    fixed={"fused_cubes": False},
    precisions=(("float32", "float32"), ("float32", "float64")),
    doc="P-V2 baseline kernel: uniforms in / weights out, XLA segment-sum",
))

register(BackendSpec(
    name="pallas-fused",
    fill=fill_mod.fill_pallas,
    capabilities=frozenset({SHARDABLE, VMAPPABLE, IN_KERNEL_RNG,
                            CLOSURE_HOISTING, EARLY_STOP}),
    knobs=("interpret", "tile"),
    fixed={"fused_cubes": True},
    precisions=(("float32", "float32"), ("float32", "float64")),
    doc="P-V3 streaming kernel: in-kernel RNG + in-kernel cube moments",
))

register(BackendSpec(
    name="pallas-gpu",
    fill=fill_mod.fill_pallas_gpu,
    capabilities=frozenset({SHARDABLE, VMAPPABLE, IN_KERNEL_RNG,
                            CLOSURE_HOISTING, EARLY_STOP}),
    knobs=("interpret", "block", "num_warps"),
    precisions=(("float32", "float32"), ("float32", "float64")),
    family="gpu",
    doc="Triton-lowered fill: scatter/atomic cube accumulation, "
        "block-privatized histograms, in-kernel RNG (DESIGN.md §14)",
))
