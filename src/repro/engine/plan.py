"""Plan layer: validate one (workload, config) combination before tracing.

``make_plan`` turns a workload (`Integrand` or `IntegrandFamily`), a
`VegasConfig`, and an `ExecutionConfig` into an immutable :class:`Plan` —
the executor's sole input.  Every cross-axis constraint is checked HERE,
against the backend registry's declared capabilities, so an unsupported
combination fails with a one-line :class:`PlanError` naming the axis and the
fix — never with a tracer error from deep inside ``vmap``/``shard_map``/
Pallas lowering (DESIGN.md §9 validation rules).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.batch.family import IntegrandFamily
from repro.core import integrator as core

from . import backends as backends_mod
from . import sharding as sharding_mod
from . import config as config_mod
from .config import (BATCH_MODES, GRAD_MODES, CheckpointPolicy,
                     ExecutionConfig, GradPolicy, PrecisionPolicy, StopPolicy)


class PlanError(ValueError):
    """An invalid execution-plan combination, rejected at plan time."""


@dataclasses.dataclass(frozen=True)
class Plan:
    """A validated, fully-resolved execution plan (what `execute` runs)."""
    workload: Any                       # Integrand | IntegrandFamily
    cfg: core.ResolvedConfig            # algorithm parameters, resolved
    execution: ExecutionConfig
    backend: backends_mod.BackendSpec
    is_family: bool                     # workload has a scenario axis
    batched: bool                       # True => vmapped family program
    batch_size: int                     # scenarios (1 for a single Integrand)
    mesh: Any                           # None when unsharded
    shard_axes: tuple[str, ...]
    n_shards: int
    checkpoint: CheckpointPolicy | None
    stop: StopPolicy | None             # None, or an ACTIVE policy (§10)
    grad: GradPolicy | None = None      # None, or an ACTIVE policy (§11)
    tuned: Any = None                   # TuneReport when the knobs came from
                                        # the measured cost model (§13)
    precision: PrecisionPolicy | None = None  # RESOLVED (sample, accum)
                                        # pair, both names concrete (§15)

    def describe(self) -> str:
        w = self.workload
        lines = [
            f"plan: {getattr(w, 'name', type(w).__name__)} "
            f"(dim={self.cfg.dim}, neval={self.cfg.neval}, "
            f"max_it={self.cfg.max_it})",
            f"  backend    {self.backend.name} "
            f"[{', '.join(sorted(self.backend.capabilities))}]",
            f"  batching   {'vmap B=' + str(self.batch_size) if self.batched else ('serial B=' + str(self.batch_size) if self.batch_size > 1 else 'single scenario')}",
            f"  sharding   {str(self.n_shards) + ' shards @ ' + ','.join(self.shard_axes) if self.n_shards > 1 else 'none'}",
            f"  loop       {'host (checkpointing)' if self.checkpoint else ('on-device while_loop [stop: ' + self.stop.describe() + ']' if self.stop else 'on-device fori_loop')}",
            f"  grad       {self.grad.describe() + ' (two-phase: stop_gradient adapt -> frozen-map eval, §11)' if self.grad else 'off'}",
        ]
        if self.precision is not None:
            p = self.precision
            note = ("" if p.accum_dtype == p.sample_dtype else
                    " (products stay in the sample dtype; running sums "
                    "widened, §15)")
            lines.append(f"  precision  {p.describe()}{note}")
        if self.tuned is not None:
            lines.append(f"  knobs      {self.tuned.describe()}")
        return "\n".join(lines)


def make_plan(workload, cfg: core.VegasConfig | None = None,
              execution: ExecutionConfig | None = None) -> Plan:
    """Resolve + validate one run.  ``execution=None`` takes the config's own
    ``cfg.execution``; passing both lets callers keep one algorithm config
    and vary the execution axes (the sweep CLI does this)."""
    cfg = cfg or core.VegasConfig()
    if execution is None:
        execution = cfg.execution
    elif execution is not cfg.execution:
        cfg = cfg.with_execution(execution)
    if execution.backend == "auto":
        # Resolve the platform default (pallas-fused on TPU, pallas-gpu on
        # GPU, ref elsewhere) BEFORE the autotuner and the capability checks,
        # so both see the concrete backend and the Plan records it.
        from repro import kernels
        execution = dataclasses.replace(
            execution, backend=kernels.backend_default())
        cfg = cfg.with_execution(execution)
    tuned = None
    if execution.autotune:
        # §13: the cost-model chooser replaces cfg's chunk/tile/batch/shard
        # knobs with the predicted-fastest VALID combination (candidates are
        # probed through make_plan itself with autotune=False, so the tuner
        # cannot emit a plan this function would reject — and its fallback
        # is the caller's own knobs, so autotuning never loses a plan that
        # explicit knobs would have admitted).
        from . import autotune as autotune_mod
        cfg, tuned = autotune_mod.tune(workload, cfg)
        execution = cfg.execution
    rcfg = cfg.resolve(workload.dim)

    # --- backend axis -------------------------------------------------------
    try:
        spec = backends_mod.get(execution.backend)
    except KeyError as e:
        raise PlanError(str(e)) from None
    # Normalize any jnp.dtype()-accepted spelling before comparing against
    # the spec's declared names ('f4', np.float64, jnp.float32, ... all ok).
    dtype_name = jnp.dtype(rcfg.dtype).name
    if dtype_name not in spec.dtypes:
        raise PlanError(
            f"backend {spec.name!r} supports dtypes {spec.dtypes}, got "
            f"dtype={dtype_name!r}"
            + (" (the in-kernel RNG reproduces the f32 uniform bit pattern)"
               if spec.supports(backends_mod.IN_KERNEL_RNG) else ""))

    # --- precision axis (§15) ----------------------------------------------
    prec = execution.precision
    if prec is not None and prec.sample_dtype is not None:
        sample_name = jnp.dtype(prec.sample_dtype).name
        if sample_name != dtype_name:
            raise PlanError(
                f"PrecisionPolicy(sample_dtype={sample_name!r}) conflicts "
                f"with cfg.dtype={dtype_name!r} — the sample dtype has one "
                f"source of truth; leave sample_dtype=None to inherit it")
    accum_name = (jnp.dtype(prec.accum_dtype).name
                  if prec is not None and prec.accum_dtype is not None
                  else dtype_name)
    if (dtype_name, accum_name) not in spec.precisions:
        pairs = ", ".join(f"{s}->{a}" for s, a in spec.precisions)
        raise PlanError(
            f"backend {spec.name!r} supports precision pairs [{pairs}], got "
            f"{dtype_name}->{accum_name}")
    import jax.dtypes as _jdtypes
    if accum_name != dtype_name and \
            _jdtypes.canonicalize_dtype(accum_name).name != accum_name:
        # jnp silently narrows f64 arrays when x64 is off — a widened
        # accumulator would silently degrade to the plain-f32 run.
        raise PlanError(
            f"accum_dtype={accum_name!r} needs x64 enabled: set "
            f"JAX_ENABLE_X64=1 / call repro.launch.env.enable_x64(True) "
            f"before building programs")
    precision = config_mod.PrecisionPolicy(sample_dtype=dtype_name,
                                           accum_dtype=accum_name)
    # The knob universe comes from the registry itself, so a knob added to
    # one BackendSpec is automatically validated against every other.
    all_knobs = set().union(*(backends_mod.get(n).knobs
                              for n in backends_mod.available()))
    for knob in sorted(all_knobs):
        if (getattr(execution, knob, None) is not None
                and knob not in spec.knobs):
            raise PlanError(
                f"{knob}={getattr(execution, knob)!r} is not a knob of "
                f"backend {spec.name!r} (accepted: {spec.knobs or 'none'})")

    # --- batch axis ---------------------------------------------------------
    is_family = isinstance(workload, IntegrandFamily) or (
        hasattr(workload, "params") and hasattr(workload, "bind"))
    if execution.batch not in BATCH_MODES:
        raise PlanError(f"batch={execution.batch!r} is not one of {BATCH_MODES}")
    if not is_family:
        if execution.batch == "vmap":
            raise PlanError(
                f"batch='vmap' needs an IntegrandFamily workload with a "
                f"scenario axis; got a plain integrand "
                f"{getattr(workload, 'name', workload)!r}")
        batched, batch_size = False, 1
    else:
        batch_size = workload.batch_size
        if execution.batch == "serial":
            batched = False
        else:
            if not spec.supports(backends_mod.VMAPPABLE):
                if execution.batch == "vmap":
                    raise PlanError(
                        f"backend {spec.name!r} does not declare "
                        f"'{backends_mod.VMAPPABLE}'; use batch='serial' or a "
                        f"vmappable backend ({_caps(backends_mod.VMAPPABLE)})")
                batched = False   # auto: fall back to the serial loop
            else:
                batched = True

    # --- sharding axis ------------------------------------------------------
    mesh, shard_axes, n_shards = execution.mesh, execution.shard_axes, 1
    if shard_axes and mesh is None:
        raise PlanError(f"shard_axes={shard_axes} given without a mesh")
    if mesh is not None:
        shard_axes = tuple(shard_axes or mesh.axis_names)
        missing = [a for a in shard_axes if a not in mesh.axis_names]
        if missing:
            raise PlanError(
                f"shard axes {missing} not in mesh axes "
                f"{tuple(mesh.axis_names)}")
        n_shards = sharding_mod.mesh_shard_count(mesh, shard_axes)
        if n_shards > 1 and not spec.supports(backends_mod.SHARDABLE):
            raise PlanError(
                f"backend {spec.name!r} does not declare "
                f"'{backends_mod.SHARDABLE}'; shardable backends: "
                f"{_caps(backends_mod.SHARDABLE)}")
        if n_shards > rcfg.n_cap // rcfg.chunk:
            # Merely-uneven divisions are fine (trailing shards accumulate
            # masked zeros, DESIGN.md C2); rejected is only the degenerate
            # case where shards outnumber chunks, i.e. devices cannot own
            # work even at one chunk apiece.
            raise PlanError(
                f"{n_shards} shards but only {rcfg.n_cap // rcfg.chunk} "
                f"chunks: more devices than units of work — lower the "
                f"device count or the chunk size ({rcfg.chunk})")
    else:
        shard_axes = ()

    # --- checkpoint axis ----------------------------------------------------
    ckpt = execution.checkpoint
    if ckpt is not None:
        if is_family:
            raise PlanError(
                "checkpointing is a single-scenario, host-loop policy; a "
                "family run restarts from the warm-start map cache "
                "(batch.cache.MapCache) instead")
        if ckpt.directory is None and ckpt.callback is None:
            raise PlanError(
                "CheckpointPolicy needs a directory or a callback")

    # --- stop axis ----------------------------------------------------------
    stop = execution.stop
    if stop is not None:
        if stop.rtol < 0 or stop.atol < 0 or stop.min_it < 0:
            raise PlanError(
                f"StopPolicy fields must be non-negative, got "
                f"rtol={stop.rtol}, atol={stop.atol}, min_it={stop.min_it}")
        if not stop.active:
            stop = None  # rtol == atol == 0: inert, run the fixed loop
    if stop is not None:
        if ckpt is not None:
            raise PlanError(
                "stop + checkpoint conflict: a StopPolicy runs the "
                "on-device while_loop, a CheckpointPolicy forces the "
                "per-iteration host loop — drop one (resuming FROM a "
                "checkpoint into a stop-policy run is supported: pass the "
                "restored state to run/execute)")
        if not spec.supports(backends_mod.EARLY_STOP):
            raise PlanError(
                f"backend {spec.name!r} does not declare "
                f"'{backends_mod.EARLY_STOP}'; early-stop capable backends: "
                f"{_caps(backends_mod.EARLY_STOP)}")
        if stop.min_it >= rcfg.max_it:
            raise PlanError(
                f"StopPolicy(min_it={stop.min_it}) >= max_it="
                f"{rcfg.max_it}: the policy could never stop early — "
                f"lower min_it or drop the policy")

    # --- grad axis ----------------------------------------------------------
    grad = execution.grad
    if grad is not None:
        if grad.mode not in GRAD_MODES:
            raise PlanError(
                f"GradPolicy.mode={grad.mode!r} is not one of {GRAD_MODES}")
        if not grad.active:
            grad = None  # mode='off': inert, plain run
    if grad is not None:
        cap = (backends_mod.GRAD_PATHWISE if grad.mode == "pathwise"
               else backends_mod.GRAD_SCORE)
        if not spec.supports(cap):
            hint = (" (the fused kernel regenerates its RNG in-kernel — "
                    "there is no JAX-level sample path to differentiate; "
                    "use 'ref' or 'pallas')"
                    if spec.supports(backends_mod.IN_KERNEL_RNG) else "")
            raise PlanError(
                f"backend {spec.name!r} does not declare '{cap}'; "
                f"grad-capable backends for mode={grad.mode!r}: "
                f"{_caps(cap)}{hint}")
        if ckpt is not None:
            raise PlanError(
                "grad + checkpoint conflict: the two-phase differentiable "
                "run is one traced program, a CheckpointPolicy forces the "
                "per-iteration host loop — drop one")
        if n_shards > 1:
            raise PlanError(
                "grad + mesh is not supported yet: the differentiable eval "
                "pass is not wired through shard_map — drop the mesh (the "
                "adapt phase alone does not dominate grad runs)")
        if accum_name != dtype_name:
            raise PlanError(
                "grad + widened accumulation is not supported yet: the "
                "two-phase custom VJP/JVP primal types are the sample "
                "dtype — drop the PrecisionPolicy or the GradPolicy")

    return Plan(workload=workload, cfg=rcfg, execution=execution,
                backend=spec, is_family=is_family, batched=batched,
                batch_size=batch_size, mesh=mesh, shard_axes=shard_axes,
                n_shards=n_shards, checkpoint=ckpt, stop=stop, grad=grad,
                tuned=tuned, precision=precision)


def _caps(capability: str) -> list[str]:
    return [n for n in backends_mod.available()
            if backends_mod.get(n).supports(capability)]
