"""Sharding mechanics: partition the fill's global chunk axis over a mesh.

The distribution contract is DESIGN.md C5: chunk ``g`` draws its samples from
``fold_in(key_it, g)`` and finds its cubes from the global offset
``g * chunk``, so a shard's numbers are a pure function of ``(key, chunk
range)`` — independent of device identity, count, or order.  Sharding is a
static partition of ``range(n_cap // chunk)`` plus one psum.

Two composition shapes, both built on :func:`make_local_fill`:

  * :func:`make_sharded_fill` wraps ONE fill call in its own ``shard_map`` —
    a drop-in ``fill_fn`` for `core.integrator.iteration_step` (what
    `repro.dist` re-exports, and what the host-loop/checkpoint path uses);
  * the executor's sharded **batched** program instead wraps the ENTIRE
    vmapped run in one ``shard_map`` and calls the local fill inside it —
    B scenarios × D devices as one jitted program (DESIGN.md §9.3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6: shard_map graduated out of experimental
    from jax import shard_map as shard_map
except ImportError:  # jax <= 0.5.x
    from jax.experimental.shard_map import shard_map

from . import backends as backends_mod

REPLICATED = P()


def mesh_shard_count(mesh, axis_names) -> int:
    """Number of fill shards = product of the mesh extents being sharded over."""
    n = 1
    for a in axis_names:
        n *= mesh.shape[a]
    return n


def shard_chunk_range(total_chunks: int, shard: int, n_shards: int):
    """Contiguous chunk range ``[start, start + count)`` owned by ``shard``.

    Every shard gets the same static ``count`` (ceil division) so all devices
    compile and run the identical scanned program; shards whose range extends
    past ``total_chunks`` simply accumulate zeros there (overflow-bucket
    masking, DESIGN.md C2).  Ranges partition ``[0, n_shards * count)`` and
    are disjoint, so summing every shard's partial reproduces the global fill.
    """
    count = -(-total_chunks // n_shards)
    return shard * count, count


def linear_shard_index(mesh, axis_names):
    """Row-major linear shard index over the named mesh axes.  Only valid
    inside a ``shard_map`` body over those axes."""
    idx = jnp.zeros((), jnp.int32)
    for a in axis_names:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def make_local_fill(rcfg, mesh, axis_names, *, backend: str | None = None):
    """The per-shard fill + psum, for use INSIDE a ``shard_map`` body.

    ``fill(edges, n_h, key, integrand)`` computes this shard's chunk range
    with the registered backend (Kahan-compensated so partials are exact to
    ~1 ulp, DESIGN.md D4) and psum-reduces over ``axis_names`` — every
    device returns the identical replicated :class:`FillResult`.

    The compensation survives the shard boundary: each shard returns its
    ``(sums, comp)`` pair (``return_comp=True``) and BOTH are psum-reduced,
    so the combined result is ``psum(sums) - psum(comp)`` — the corrected
    total.  Psumming the raw sums alone would throw the per-shard
    compensations away at exactly the reduction step the Kahan carry exists
    to protect, re-introducing device-count-dependent drift at hostile
    scales (DESIGN.md §15).
    """
    axis_names = tuple(axis_names)
    n_shards = mesh_shard_count(mesh, axis_names)
    total_chunks = rcfg.n_cap // rcfg.chunk
    _, per_shard = shard_chunk_range(total_chunks, 0, n_shards)
    shard_fill = backends_mod.bind_fill(rcfg, backend=backend, kahan=True,
                                        return_comp=True)

    def fill(edges, n_h, key, integrand):
        idx = linear_shard_index(mesh, axis_names)
        part, comp = shard_fill(edges, n_h, key, integrand,
                                start_chunk=idx * per_shard,
                                n_chunks=per_shard)
        total = jax.tree.map(lambda x: jax.lax.psum(x, axis_names), part)
        resid = jax.tree.map(lambda x: jax.lax.psum(x, axis_names), comp)
        return jax.tree.map(jnp.subtract, total, resid)

    return fill


def replicated_shard_map(body, mesh, n_args: int):
    """Wrap ``body`` in a replicated-in / replicated-out ``shard_map``.

    ``check_rep=False``: ``pallas_call`` has no replication rule under
    shard_map, and the psum inside the body already replicates every output
    explicitly (each device computes the identical O(KB) adaptation state;
    only the fill is divided).
    """
    return shard_map(body, mesh=mesh,
                     in_specs=(REPLICATED,) * n_args,
                     out_specs=REPLICATED, check_rep=False)


def make_stop_sync(axis_names):
    """All-shards agreement on the adaptive loop's continue decision (§10).

    For use INSIDE a ``shard_map`` body that runs the stop-policy
    ``while_loop`` (the sharded batched program): ``sync(cont)`` pmin-reduces
    the boolean over ``axis_names``, so the loop continues only while EVERY
    shard wants to.  Each shard computes the identical replicated statistics
    (the fill is already psum-reduced), making the reduction a formality —
    but the explicit agreement guarantees the while_loop trip count cannot
    diverge across devices even if a backend's reduction order ever did.

    The single-scenario sharded path needs no sync: there the ``shard_map``
    wraps only the fill, the while_loop runs outside it on replicated
    values, and no mesh axis is in scope at the decision point.
    """
    axis_names = tuple(axis_names)

    def sync(cont):
        return jax.lax.pmin(cont.astype(jnp.int32), axis_names) > 0

    return sync


def make_sharded_fill(mesh, axis_names, resolved_cfg,
                      backend: str | None = None):
    """Build a drop-in ``fill_fn`` for ``core.integrator.iteration_step``.

    ``fill_fn(edges, n_h, key, integrand)`` shard_maps the configured fill
    backend (default: the config's own) over the mesh axes named in
    ``axis_names`` and psum-reduces the per-shard partials, returning the
    same replicated result on every device.  Works eagerly and under jit
    (``run`` jits the whole iteration around it, so adaptation stays
    on-device, C4/C6).
    """
    rc = resolved_cfg
    axis_names = tuple(axis_names)
    local_fill = make_local_fill(rc, mesh, axis_names, backend=backend)

    def fill_fn(edges, n_h, key, integrand):
        body = lambda e, nh, k: local_fill(e, nh, k, integrand)
        return replicated_shard_map(body, mesh, 3)(edges, n_h, key)

    return fill_fn
