"""Plan autotuner: a measured cost model that picks execution knobs per plan
(DESIGN.md §13).

The engine exposes five execution knobs — ``chunk``, ``tile``, backend
execution mode, batch split, shard axes — that were each defaulted
independently (``chunk`` is whatever the caller passed, ``tile`` comes from a
static VMEM model, ...).  cuVegas' central performance claim rests on fitting
the workload distribution to the hardware instead of fixed heuristics; this
module is that piece for our engine, and it is what makes later hardware
ports self-tuning instead of re-defaulted.

Three layers:

  * **calibration** (:func:`calibrate`, driven by
    ``benchmarks/bench_calibrate.py``): time the jitted fill hot path over a
    small grid of (backend, d, neval, chunk, tile) shapes — steady-state,
    compile excluded — and fit per-class :class:`ClassCoeffs` by
    non-negative least squares.  The fitted :class:`CostTable` is keyed by
    (device kind, jax backend, git sha) and persists as JSON next to the
    BENCH_*.json artifacts;
  * **prediction** (:meth:`ClassCoeffs.fill_s` / :func:`predict_run_s`):
    given a plan's geometry (d, ninc, n_cubes, neval, B, mesh), predict wall
    time for any candidate knob combination.  All coefficients are
    non-negative, so the prediction is monotone in the work terms
    (property-tested: monotone in ``neval``);
  * **choice** (:func:`tune`, invoked by ``make_plan(...,
    ExecutionConfig(autotune=True))``): enumerate candidate knob
    combinations, sort by predicted cost, and PROBE each through
    ``make_plan`` itself until one validates.  Validity is never re-derived
    here — it is delegated to the registry capability/knob declarations and
    the kernel's ``ops.valid_tiles`` divisor/VMEM rules — so the tuner
    cannot emit a plan ``make_plan`` would reject, and its final fallback is
    the caller's own knobs (autotuning never loses a plan that explicit
    knobs would have admitted).

The serving layer shares the same tables: :class:`OnlineCost` keeps the
service's min-observed per-scenario-iteration cost semantics exactly and
uses a `CostTable` only as the PRIOR for classes that have not executed yet
(so a request's first batch can already be budget-enforced).
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
from typing import Any, Callable, Mapping

import numpy as np

#: Default on-disk table name (written next to BENCH_*.json by
#: ``benchmarks/bench_calibrate.py``, read back by ``resolve_table``).
DEFAULT_TABLE_PATH = "COST_TABLE.json"

#: Environment variable naming a table file (CI's autotune-smoke job sets it
#: so every --autotune run in the job shares one calibration).
TABLE_ENV = "REPRO_COST_TABLE"

#: Candidate chunk sizes the tuner enumerates (powers of two; the caller's
#: own chunk is always added so the tuner can only deviate when the model
#: predicts a strict win).
CHUNK_CANDIDATES = tuple(1 << p for p in range(9, 18))  # 512 .. 131072


def _git_sha() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short=12", "HEAD"],
                              capture_output=True, text=True,
                              timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def device_kind() -> str:
    """The cost-table device key, e.g. ``'cpu'`` / ``'TPU v4'``."""
    import jax
    try:
        return jax.devices()[0].device_kind
    except Exception:
        return "unknown"


def class_key(backend: str, interpret: bool | None = None) -> str:
    """The cost-model class of a (backend, execution-mode) pair, qualified
    by the device that produced the timings (``'ref@cpu'``,
    ``'pallas-gpu|compiled@NVIDIA H100'``).

    Backends without an ``interpret`` knob key by name alone; pallas
    backends split interpreter vs compiled timings into separate classes
    (``'pallas-fused|interpret'``) because the two are orders of magnitude
    apart — one fitted line cannot cover both.  The execution mode resolves
    against the backend's declared ``family`` (Mosaic kernels compile on
    TPU, the Triton one on GPU), and the ``@device_kind`` qualifier keeps
    timings from different silicon apart the same way — an A100 fit must
    not predict for an H100.  Lookup falls back to the unqualified class
    (`CostTable.coeffs`), so pre-qualification tables keep working.
    """
    from repro import kernels
    from . import backends as backends_mod
    spec = backends_mod.get(backend)
    if "interpret" not in spec.knobs:
        base = backend
    else:
        mode = ("interpret"
                if kernels.resolve_interpret(interpret, spec.family)
                else "compiled")
        base = f"{backend}|{mode}"
    return f"{base}@{device_kind()}"


# --- the fitted model --------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClassCoeffs:
    """Fitted fill-cost coefficients of one backend class (seconds).

    One fill call of B scenarios costs::

        t_fill = c_fixed
               + c_eval_dim  * (B * n_cap * d)       # per-eval-coordinate work
               + c_chunk     * (B * n_chunks)        # per-scan-step overhead
               + c_tile_step * (B * n_cap / tile)    # pallas grid steps only

    ``c_eval_dim`` is what makes chunk-induced ``n_cap`` padding
    (`VegasConfig.resolve` rounds ``n_cap`` up to a chunk multiple) a
    first-class cost; ``c_chunk`` is what keeps the tuner from collapsing to
    tiny chunks.  ``iter_overhead_s`` covers the non-fill part of an
    iteration (map/stratification adaptation + aggregation) per scenario.
    Every coefficient is non-negative by construction (:func:`_nnls`), so
    predictions are monotone in each work term.
    """
    c_fixed: float = 0.0
    c_eval_dim: float = 0.0
    c_chunk: float = 0.0
    c_tile_step: float = 0.0
    iter_overhead_s: float = 0.0
    n_samples: int = 0

    def fill_s(self, *, b: int, d: int, n_cap: int, n_chunks: int,
               tile: int | None = None) -> float:
        t = (self.c_fixed + self.c_eval_dim * b * n_cap * d
             + self.c_chunk * b * n_chunks)
        if tile:
            t += self.c_tile_step * (b * n_cap / tile)
        return t

    def iteration_s(self, *, b: int, d: int, n_cap: int, n_chunks: int,
                    tile: int | None = None) -> float:
        return (self.fill_s(b=b, d=d, n_cap=n_cap, n_chunks=n_chunks,
                            tile=tile) + self.iter_overhead_s * b)


#: Order-of-magnitude CPU constants (fitted on a 1-core CPU dev box) — the
#: fallback when no calibrated table is found, so ``autotune=True`` degrades
#: to sensible relative knob choices rather than an error.  Absolute
#: magnitudes only matter relative to each other: c_chunk/c_eval_dim sets
#: the padding-vs-scan-overhead tradeoff that picks the chunk.
BUILTIN_CLASSES: Mapping[str, ClassCoeffs] = {
    "ref": ClassCoeffs(c_fixed=2e-3, c_eval_dim=2e-7, c_chunk=1e-3,
                       iter_overhead_s=1e-3),
    "pallas|interpret": ClassCoeffs(c_fixed=5e-3, c_eval_dim=2e-5,
                                    c_chunk=5e-3, c_tile_step=2e-4,
                                    iter_overhead_s=1e-3),
    "pallas-fused|interpret": ClassCoeffs(c_fixed=5e-3, c_eval_dim=2e-6,
                                          c_chunk=2e-3, c_tile_step=2e-4,
                                          iter_overhead_s=1e-3),
    # Compiled-Mosaic estimates (no TPU in the calibration loop yet): the
    # per-eval term drops ~3 orders of magnitude and the per-grid-step term
    # dominates, which is exactly the regime the static VMEM autotune's
    # largest-tile preference encodes.
    "pallas|compiled": ClassCoeffs(c_fixed=1e-4, c_eval_dim=5e-10,
                                   c_chunk=2e-5, c_tile_step=2e-6,
                                   iter_overhead_s=2e-4),
    "pallas-fused|compiled": ClassCoeffs(c_fixed=1e-4, c_eval_dim=2e-10,
                                         c_chunk=2e-5, c_tile_step=2e-6,
                                         iter_overhead_s=2e-4),
    # The Triton kernel interprets a little slower than the Mosaic one (the
    # per-block one-hot partials cost more under the interpreter than the
    # windowed matmul); compiled estimates sit at the paper's GPU fill
    # throughput order of magnitude (cuVegas Table 1, ~1e9 evals/s) until a
    # real-GPU calibration lands a measured '...@<device_kind>' class.
    "pallas-gpu|interpret": ClassCoeffs(c_fixed=5e-3, c_eval_dim=4e-6,
                                        c_chunk=2e-3, c_tile_step=2e-4,
                                        iter_overhead_s=1e-3),
    "pallas-gpu|compiled": ClassCoeffs(c_fixed=5e-5, c_eval_dim=3e-10,
                                       c_chunk=1e-5, c_tile_step=1e-6,
                                       iter_overhead_s=2e-4),
}


@dataclasses.dataclass(frozen=True)
class CostTable:
    """Per-device fitted cost tables: one :class:`ClassCoeffs` per backend
    class, keyed by the environment that produced them."""
    device_kind: str = "unknown"
    jax_backend: str = "unknown"
    git_sha: str = "unknown"
    source: str = "builtin"       # builtin | calibrated | a file path
    calibration_wall_s: float = 0.0
    classes: Mapping[str, ClassCoeffs] = dataclasses.field(
        default_factory=dict)

    def coeffs(self, key: str) -> ClassCoeffs:
        """Coefficients for a class, falling back exact -> device-qualified
        sibling mode -> unqualified -> unqualified sibling -> builtin -> ref
        so prediction never KeyErrors (an uncalibrated class still gets
        order-of-magnitude-sane relative choices, and a table calibrated
        before device qualification keeps serving qualified lookups)."""
        base, _, dev = key.partition("@")
        sib = None
        if "|" in base:
            name, mode = base.split("|", 1)
            sib = f"{name}|{'compiled' if mode == 'interpret' else 'interpret'}"
        tries = [key]
        if dev:
            if sib:
                tries.append(f"{sib}@{dev}")
            tries.append(base)
        if sib:
            tries.append(sib)
        for k in tries:
            got = self.classes.get(k)
            if got is not None:
                return got
        return BUILTIN_CLASSES.get(base) or BUILTIN_CLASSES["ref"]

    def to_json(self) -> dict:
        return {
            "device_kind": self.device_kind,
            "jax_backend": self.jax_backend,
            "git_sha": self.git_sha,
            "source": self.source,
            "calibration_wall_s": round(self.calibration_wall_s, 3),
            "classes": {k: dataclasses.asdict(v)
                        for k, v in self.classes.items()},
        }

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        return path

    @classmethod
    def load(cls, path: str) -> "CostTable":
        with open(path) as f:
            obj = json.load(f)
        classes = {k: ClassCoeffs(**v)
                   for k, v in obj.get("classes", {}).items()}
        return cls(device_kind=obj.get("device_kind", "unknown"),
                   jax_backend=obj.get("jax_backend", "unknown"),
                   git_sha=obj.get("git_sha", "unknown"),
                   source=path,
                   calibration_wall_s=obj.get("calibration_wall_s", 0.0),
                   classes=classes)


BUILTIN_TABLE = CostTable(classes=BUILTIN_CLASSES)


def resolve_table(cost_table: Any = None) -> CostTable:
    """Find the cost table for this process, in priority order:

      1. ``cost_table`` (an `ExecutionConfig.cost_table`: a `CostTable` or a
         path string);
      2. ``$REPRO_COST_TABLE`` (CI's autotune-smoke job);
      3. ``./COST_TABLE.json`` (what ``bench_calibrate`` writes);
      4. the builtin order-of-magnitude table.

    A missing/unreadable explicit path raises; the implicit fallbacks are
    silent (autotuning must work out of the box).
    """
    if isinstance(cost_table, CostTable):
        return cost_table
    if isinstance(cost_table, str):
        return CostTable.load(cost_table)
    env = os.environ.get(TABLE_ENV)
    if env:
        return CostTable.load(env)
    if os.path.exists(DEFAULT_TABLE_PATH):
        try:
            return CostTable.load(DEFAULT_TABLE_PATH)
        except (OSError, ValueError, KeyError, TypeError):
            return BUILTIN_TABLE
    return BUILTIN_TABLE


# --- fitting -----------------------------------------------------------------

def _nnls(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Non-negative least squares without scipy: solve OLS on the active
    feature set, drop the most negative coefficient, repeat.  Exact enough
    for our tiny (<= 4-column) designs, and it guarantees the monotone
    predictions the chooser relies on."""
    active = list(range(x.shape[1]))
    coef = np.zeros(x.shape[1])
    while active:
        c, *_ = np.linalg.lstsq(x[:, active], y, rcond=None)
        if (c >= 0.0).all():
            coef[np.asarray(active)] = c
            break
        active.pop(int(np.argmin(c)))
    return coef


def fit_class(samples: list[dict]) -> ClassCoeffs:
    """Fit one class's coefficients from calibration samples (dicts with
    ``b, d, n_cap, n_chunks, tile (or None), seconds``)."""
    has_tile = any(s.get("tile") for s in samples)
    rows, y = [], []
    for s in samples:
        b = s.get("b", 1)
        row = [1.0, b * s["n_cap"] * s["d"], b * s["n_chunks"]]
        if has_tile:
            row.append(b * s["n_cap"] / s["tile"] if s.get("tile") else 0.0)
        rows.append(row)
        y.append(s["seconds"])
    coef = _nnls(np.asarray(rows, np.float64), np.asarray(y, np.float64))
    return ClassCoeffs(
        c_fixed=float(coef[0]), c_eval_dim=float(coef[1]),
        c_chunk=float(coef[2]),
        c_tile_step=float(coef[3]) if has_tile else 0.0,
        n_samples=len(samples))


# --- calibration -------------------------------------------------------------

#: The calibration grids: small enough that fast mode completes in ~1 minute
#: on one CPU core (pallas-interpret fill costs ~0.2 ms/eval, which is why
#: its shapes are tiny), varied enough that every fitted feature moves.
_REF_GRID_FAST = dict(dims=(4, 10), nevals=(16_384, 65_536),
                      chunks=(1_024, 4_096, 16_384))
_REF_GRID_FULL = dict(dims=(4, 6, 10), nevals=(16_384, 65_536, 262_144),
                      chunks=(1_024, 4_096, 16_384, 65_536))
_PALLAS_GRID_FAST = dict(dims=(4,), nevals=(1_024, 4_096),
                         chunks=(512, 1_024), tiles=(64, 256))
_PALLAS_GRID_FULL = dict(dims=(4,), nevals=(1_024, 4_096, 16_384),
                         chunks=(512, 1_024, 4_096), tiles=(32, 128, 512))


def _time_steady(fn, *args, repeats: int = 2) -> float:
    """Median steady-state wall of ``fn(*args)``: one warmup call pays
    trace+compile, the measured repeats reuse the executable — the regime a
    long-lived run/service amortizes into, and the one the knobs actually
    move (compile time is knob-insensitive noise several times larger than
    the per-call effects being fitted)."""
    import time as _time
    import jax
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = _time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(_time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _fill_sample(backend: str, dim: int, neval: int, chunk: int,
                 step: int | None, *, step_knob: str = "tile",
                 ninc: int = 64, repeats: int = 2) -> dict:
    """Time one jitted steady-state fill of one (backend, shape, knob)
    point; returns the fitted-feature sample.  ``step`` is the backend's
    grid-step knob (``tile`` on the Mosaic kernels, ``block`` on the Triton
    one) — both fit the same per-grid-step cost feature."""
    import functools

    import jax

    from repro.core import integrator as core
    from repro.core import map as vmap_
    from repro.core import strat
    from repro.core.integrands import make_cosine
    from .config import ExecutionConfig
    from . import backends as backends_mod

    execution = ExecutionConfig(backend=backend, **{step_knob: step})
    cfg = core.VegasConfig(neval=neval, ninc=ninc, chunk=chunk,
                           execution=execution)
    rcfg = cfg.resolve(dim)
    ig = make_cosine(dim=dim)
    fill_fn = backends_mod.bind_fill(rcfg, backend=backend)
    edges = vmap_.uniform_edges(ig.lower, ig.upper, rcfg.ninc, rcfg.dtype)
    n_h = strat.uniform_nh(rcfg.neval, rcfg.n_cubes)
    key = jax.random.PRNGKey(0)
    prog = jax.jit(functools.partial(
        lambda e, n, k, f: f(e, n, k, ig), f=fill_fn))
    seconds = _time_steady(prog, edges, n_h, key, repeats=repeats)
    return dict(b=1, d=dim, n_cap=rcfg.n_cap,
                n_chunks=rcfg.n_cap // rcfg.chunk, tile=step,
                chunk=rcfg.chunk, neval=neval, seconds=seconds)


def _iter_overhead(dim: int = 4, neval: int = 16_384,
                   chunk: int = 4_096) -> float:
    """Per-scenario non-fill iteration cost: time one full jitted
    `iteration_step` and subtract the same-shape fill.  Backend-independent
    (adaptation/aggregation never touch the kernel), so one measurement
    serves every class."""
    import functools

    import jax

    from repro.core import integrator as core
    from repro.core.integrands import make_cosine

    cfg = core.VegasConfig(neval=neval, ninc=64, chunk=chunk)
    rcfg = cfg.resolve(dim)
    ig = make_cosine(dim=dim)
    state = core.init_state(ig, rcfg, jax.random.PRNGKey(0))
    step = jax.jit(functools.partial(core.iteration_step, integrand=ig,
                                     cfg=rcfg))
    t_step = _time_steady(step, state)
    t_fill = _fill_sample("ref", dim, neval, chunk, None)["seconds"]
    return max(t_step - t_fill, 0.0)


def calibrate(*, fast: bool = True, backends: tuple[str, ...] | None = None,
              repeats: int = 2,
              emit: Callable[[str, dict], None] | None = None) -> CostTable:
    """Measure the fill/adapt hot paths over the calibration grid and fit a
    :class:`CostTable` for the current device.

    ``backends=None`` calibrates every registry backend in its
    platform-resolved execution mode (interpreted pallas on CPU/GPU,
    compiled on TPU).  ``emit(name, sample)`` lets the benchmark harness
    record each measured point as a BENCH row.
    """
    import time as _time

    import jax

    from . import backends as backends_mod

    t0 = _time.perf_counter()
    if backends is None:
        backends = backends_mod.available()
    overhead = _iter_overhead()
    classes: dict[str, ClassCoeffs] = {}
    for backend in backends:
        spec = backends_mod.get(backend)
        key = class_key(backend)
        step_knob = next((k for k in ("tile", "block") if k in spec.knobs),
                         None)
        grid = ((_PALLAS_GRID_FAST if fast else _PALLAS_GRID_FULL)
                if step_knob
                else (_REF_GRID_FAST if fast else _REF_GRID_FULL))
        samples = []
        for d in grid["dims"]:
            for neval in grid["nevals"]:
                for chunk in grid["chunks"]:
                    for step in grid.get("tiles", (None,)) if step_knob \
                            else (None,):
                        s = _fill_sample(backend, d, neval, chunk, step,
                                         step_knob=step_knob or "tile",
                                         repeats=repeats)
                        s["class"] = key
                        samples.append(s)
                        if emit is not None:
                            emit(f"calibrate/{key}/d={d}/neval={neval}"
                                 f"/chunk={s['chunk']}"
                                 + (f"/{step_knob}={step}" if step else ""),
                                 s)
        classes[key] = dataclasses.replace(fit_class(samples),
                                           iter_overhead_s=overhead)
    return CostTable(device_kind=device_kind(),
                     jax_backend=jax.default_backend(), git_sha=_git_sha(),
                     source="calibrated",
                     calibration_wall_s=_time.perf_counter() - t0,
                     classes=classes)


# --- prediction --------------------------------------------------------------

def predict_run_s(coeffs: ClassCoeffs, rcfg, *, b: int = 1,
                  tile: int | None = None, n_shards: int = 1) -> float:
    """Predicted whole-run wall (seconds) of ``max_it`` iterations at one
    knob combination.  Sharding divides the chunk range (`shard_chunk_range`
    ceil semantics: the critical path is the largest shard's chunk count);
    the O(KB) adaptation state is replicated, so ``iter_overhead_s`` does
    not shrink with the mesh."""
    n_chunks = rcfg.n_cap // rcfg.chunk
    shard_chunks = -(-n_chunks // max(n_shards, 1))
    fill = coeffs.fill_s(b=b, d=rcfg.dim, n_cap=shard_chunks * rcfg.chunk,
                         n_chunks=shard_chunks, tile=tile)
    return rcfg.max_it * (fill + coeffs.iter_overhead_s * b)


# --- the knob chooser --------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TuneReport:
    """What the tuner decided and why (`Plan.describe` renders this)."""
    class_key: str
    table_source: str
    device_kind: str
    chosen: Mapping[str, Any]
    default: Mapping[str, Any]
    predicted_s: float
    predicted_default_s: float

    def describe(self) -> str:
        def fmt(knobs):
            return " ".join(f"{k}={v}" for k, v in knobs.items()
                            if v is not None)
        # class_key already carries the live @device_kind qualifier; the
        # device_kind FIELD is the table's own provenance, shown only via
        # table= (a builtin table reports 'unknown').
        same = dict(self.chosen) == dict(self.default)
        return (f"autotuned[{self.class_key}, "
                f"table={self.table_source}] "
                f"{fmt(self.chosen)} (predicted {self.predicted_s:.3g}s"
                + (", same as default" if same else
                   f" vs default {fmt(self.default)} "
                   f"{self.predicted_default_s:.3g}s") + ")")


def _is_family(workload) -> bool:
    # Same duck-typing as make_plan's batch-axis check.
    return hasattr(workload, "params") and hasattr(workload, "bind")


def _accum_itemsize(execution) -> int:
    """Accumulator byte width the budget oracles must price (§15): 8 under a
    widened f64 PrecisionPolicy, else 4."""
    prec = getattr(execution, "precision", None)
    if prec is not None and prec.accum_dtype is not None:
        return int(np.dtype(prec.accum_dtype).itemsize)
    return 4


def _step_candidates(step_knob: str, chunk: int, d: int, ninc: int,
                     n_cubes: int, accum_itemsize: int = 4) -> list:
    """A small predicted-orderable subset of the kernel's valid grid steps
    (``tile`` on the Mosaic kernels, ``block`` on the Triton one): the
    static-autotune choice plus the power-of-two divisors >= 8.  All
    candidates come from the kernel's own validity oracle
    (``ops.valid_tiles`` / ``gpu_fill.valid_blocks``), so the tuner can
    never pick a step ``_pick_tile``/``_pick_block`` rejects — including
    under a widened policy, where the 8-byte accumulators shrink the valid
    set (``accum_itemsize``)."""
    if step_knob == "block":
        from repro.kernels import gpu_fill
        valid = gpu_fill.valid_blocks(chunk, d, ninc,
                                      accum_itemsize=accum_itemsize)
    else:
        from repro.kernels import ops
        valid = ops.valid_tiles(chunk, d, ninc, n_cubes,
                                accum_itemsize=accum_itemsize)
    if not valid:
        return [None]     # let the kernel's own picker raise its diagnostic
    pow2 = [t for t in valid if t >= 8 and (t & (t - 1)) == 0]
    cands = sorted(set(pow2[-3:]) | {valid[-1]}, reverse=True)
    return cands or [valid[-1]]


def tune(workload, cfg, *, table: CostTable | None = None):
    """Choose chunk/tile/batch/shard knobs for ``(workload, cfg)`` by
    minimizing the measured cost model over valid combinations.

    Returns ``(tuned_cfg, TuneReport | None)``.  The tuned config has
    ``autotune=False`` with every chosen knob pinned, so re-planning it is
    deterministic and cheap.  Pinned knobs are respected: an explicit
    ``tile=...`` or ``shard_axes=...`` is never overridden, and the caller's
    own ``chunk`` is always in the candidate set (the tuner deviates only
    when the model predicts a strict win; ties keep the default).  If the
    backend is unknown, the config is returned unchanged so ``make_plan``
    raises its own diagnostic.
    """
    from repro.core import strat
    from . import backends as backends_mod
    from . import sharding as sharding_mod
    from .plan import PlanError, make_plan

    execution = cfg.execution
    try:
        spec = backends_mod.get(execution.backend)
    except KeyError:
        return cfg, None
    if table is None:
        table = resolve_table(execution.cost_table)
    key = class_key(spec.name, execution.interpret)
    coeffs = table.coeffs(key)
    dim = workload.dim
    family = _is_family(workload)
    b = workload.batch_size if family else 1
    probe_exec = dataclasses.replace(execution, autotune=False)
    step_knob = next((k for k in ("tile", "block") if k in spec.knobs), None)
    pinned_step = getattr(execution, step_knob) if step_knob else None
    itemsize = _accum_itemsize(execution)

    # The default-knob baseline the report compares against.
    base_rcfg = cfg.resolve(dim)
    default_step = pinned_step
    if step_knob == "tile" and default_step is None:
        from repro.kernels import ops
        default_step = ops.autotune_tile(base_rcfg.chunk, dim,
                                         base_rcfg.ninc, base_rcfg.n_cubes,
                                         accum_itemsize=itemsize)
    elif step_knob == "block" and default_step is None:
        from repro.kernels import gpu_fill
        default_step = gpu_fill.autotune_block(base_rcfg.chunk, dim,
                                               base_rcfg.ninc,
                                               accum_itemsize=itemsize)
    mesh = execution.mesh
    default_axes = (execution.shard_axes if execution.shard_axes is not None
                    else (tuple(mesh.axis_names) if mesh is not None else None))
    default_shards = (sharding_mod.mesh_shard_count(mesh, default_axes)
                      if mesh is not None else 1)
    vmappable = spec.supports(backends_mod.VMAPPABLE)
    default_batch = execution.batch
    default_vmap = family and (default_batch == "vmap" or (
        default_batch == "auto" and vmappable))

    def predict(rcfg, step, n_shards, vmapped):
        # tile= is the generic per-grid-step feature; block fits it too.
        if vmapped or not family:
            return predict_run_s(coeffs, rcfg, b=b, tile=step,
                                 n_shards=n_shards)
        # Serial family: B independent programs, each paying c_fixed +
        # overhead on its own.
        return b * predict_run_s(coeffs, rcfg, b=1, tile=step,
                                 n_shards=n_shards)

    predicted_default = predict(base_rcfg, default_step, default_shards,
                                default_vmap)

    # --- candidate enumeration ----------------------------------------------
    ns = cfg.nstrat or strat.choose_nstrat(cfg.neval, dim, cfg.max_cubes)
    n_cubes = ns ** dim
    raw_cap = strat.eval_capacity(cfg.neval, n_cubes)
    chunk_cands = sorted({c for c in CHUNK_CANDIDATES
                          if c <= max(raw_cap, 256)} | {cfg.chunk})
    axes_cands: list = [execution.shard_axes]
    if mesh is not None and execution.shard_axes is None:
        axes_cands = [tuple(mesh.axis_names)]
        if len(mesh.axis_names) > 1:
            axes_cands += [(a,) for a in mesh.axis_names]
    batch_cands = ([execution.batch] if not family
                   or execution.batch != "auto" or not vmappable
                   else ["vmap", "serial"])

    combos = []
    for chunk in chunk_cands:
        ccfg = dataclasses.replace(cfg, chunk=chunk, execution=probe_exec)
        rcfg = ccfg.resolve(dim)
        steps = ([pinned_step] if step_knob is None
                 or pinned_step is not None
                 else _step_candidates(step_knob, rcfg.chunk, dim,
                                       rcfg.ninc, rcfg.n_cubes, itemsize))
        for step in steps:
            for axes in axes_cands:
                n_sh = (sharding_mod.mesh_shard_count(mesh, axes)
                        if mesh is not None and axes else 1)
                for bm in batch_cands:
                    pred = predict(rcfg, step, n_sh, bm != "serial")
                    combos.append((pred, chunk, step, axes, bm))
    # Stable sort on predicted cost alone: equal predictions keep candidate
    # order, and the caller's own chunk sorts via its position in the sorted
    # candidate list — deterministic for a fixed table (property-tested).
    combos.sort(key=lambda c: c[0])

    # --- probe: validity is make_plan's, not ours ---------------------------
    for pred, chunk, step, axes, bm in combos:
        # A tile/block on a backend without the knob is forwarded unchanged
        # (it rides along inside probe_exec) so the probe — and the fallback
        # — surface make_plan's own knob PlanError: the tuner must never
        # launder an invalid pin into a valid plan.
        cand_exec = dataclasses.replace(
            probe_exec, shard_axes=axes, batch=bm,
            **({step_knob: step} if step_knob else {}))
        cand_cfg = dataclasses.replace(cfg, chunk=chunk,
                                       execution=cand_exec)
        try:
            make_plan(workload, cand_cfg)
        except PlanError:
            continue
        report = TuneReport(
            class_key=key, table_source=table.source,
            device_kind=table.device_kind,
            chosen=dict(chunk=cand_cfg.resolve(dim).chunk, batch=bm,
                        shard_axes=axes,
                        **({step_knob: step} if step_knob else {})),
            default=dict(chunk=base_rcfg.chunk, batch=execution.batch,
                         shard_axes=execution.shard_axes,
                         **({step_knob: default_step} if step_knob else {})),
            predicted_s=pred, predicted_default_s=predicted_default)
        return cand_cfg, report
    # Nothing the model proposed validates (e.g. an exotic workload the
    # probes cannot satisfy): fall back to the caller's own knobs — by
    # construction make_plan accepts them iff it would have without
    # autotune, so autotuning never rejects a plan explicit knobs admit.
    return cfg.with_execution(probe_exec), None


# --- the serving layer's shared cost model -----------------------------------

class OnlineCost:
    """Per-class per-scenario-iteration cost for the sweep service (§12).

    Exactly the PR-7 semantics for observations: ``observe`` keeps the
    MINIMUM ``wall / (trips * B)`` ever measured for a class, so
    trace+compile-inflated samples (a class's calibration batch) never
    poison the estimate upward.  A :class:`CostTable`, when given, serves
    only as the PRIOR for classes with no observation yet — a request's
    FIRST batch can then already be budget-enforced.  Without a table the
    behavior is bit-identical to the legacy dict (first batch
    uncalibrated)."""

    def __init__(self, table: CostTable | None = None):
        self.table = table
        self._observed: dict[tuple, float] = {}

    def observe(self, key: tuple, unit_s: float) -> None:
        old = self._observed.get(key)
        self._observed[key] = (unit_s if old is None
                               else min(old, unit_s))

    def unit(self, key: tuple, *, rcfg=None, backend: str = "ref",
             interpret: bool | None = None,
             tile: int | None = None) -> float | None:
        """Per-scenario-iteration seconds: the min-observed value, else the
        table prediction (when a table and the plan geometry are given),
        else None (uncalibrated — budgets unenforced, legacy behavior)."""
        got = self._observed.get(key)
        if got is not None or self.table is None or rcfg is None:
            return got
        try:
            coeffs = self.table.coeffs(class_key(backend, interpret))
        except KeyError:
            return None
        return coeffs.iteration_s(b=1, d=rcfg.dim, n_cap=rcfg.n_cap,
                                  n_chunks=rcfg.n_cap // rcfg.chunk,
                                  tile=tile)

    @property
    def classes_calibrated(self) -> int:
        return len(self._observed)

    def snapshot(self, limit: int = 8) -> dict:
        return {str(k[0]): v
                for k, v in list(self._observed.items())[:limit]}
