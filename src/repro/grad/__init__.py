"""repro.grad: differentiable integration through the VEGAS+ loop (§11).

``differentiable(fn, dim, lower, upper, ...)`` wraps the two-phase
estimator — ``stop_gradient``-frozen adaptation, then a frozen-map
evaluation pass whose pathwise (or score-function) Monte Carlo gradient is
exact — behind a `jax.custom_vjp`/`jax.custom_jvp` boundary.  The engine
route is `GradPolicy` on `ExecutionConfig` (the sixth execution axis):
``execute(make_plan(workload, cfg, execution=ExecutionConfig(grad=
GradPolicy())))`` returns `GradResult` / `BatchGradResult`.
"""

from repro.engine.config import GRAD_MODES, GradPolicy  # noqa: F401

from .api import (  # noqa: F401
    MAX_SDEV_COMPONENTS,
    BatchGradResult,
    GradProgram,
    GradResult,
    differentiable,
    execute_grad,
)
from .estimator import (  # noqa: F401
    directional_moments,
    mode_value,
    rescale_edges,
    score_surrogate,
)

__all__ = [
    "BatchGradResult", "GRAD_MODES", "GradPolicy", "GradProgram",
    "GradResult", "MAX_SDEV_COMPONENTS", "differentiable",
    "directional_moments", "execute_grad", "mode_value", "rescale_edges",
    "score_surrogate",
]
