"""Differentiable integration: custom VJP/JVP around the VEGAS+ loop (§11).

The estimator is TWO-PHASE.  Phase one (ADAPT) runs the ordinary iteration
loop — `core.adapt_loop`, any backend, any stop policy — on
``stop_gradient``-frozen inputs, so neither ``lax.while_loop`` nor a Pallas
kernel ever sees a tangent.  Phase two (EVAL) is one fill over the frozen
``(edges, n_h)`` with the eval key ``fold_in(key, max_it)`` (a stream no
adapt iteration draws, `core.eval_key`); its value is the returned estimate
and its *pathwise* derivative is an exact Monte Carlo estimator of
``dI/dtheta``.  Unbiasedness of dropping the adapt phase from the gradient:
for ANY fixed map, ``E[eval estimate | map] = I(theta)`` — the map's own
theta-dependence therefore contributes zero expected gradient, it only
reshuffles variance (DESIGN.md §11).

The custom-AD boundary (`_make_program`) exists because the adapt loop is
*not* differentiable (while_loop carries, in-kernel RNG on pallas backends)
and must never be traced with tangents: `jax.custom_vjp`/`jax.custom_jvp`
route every cotangent/tangent through the reference eval formulation
instead, on the SAME chunk-keyed RNG stream the value pass used — the
bit-exact RNG contract is what lets a ``pallas`` primal pair with a ``ref``
cotangent (`engine.backends` ``grad-pathwise`` capability note).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from repro.core import integrator as core
from repro.core.integrands import Integrand
from repro.engine import backends as backends_mod
from repro.engine.config import GradPolicy

from .estimator import directional_moments, mode_value, rescale_edges

#: ``with_sdev`` integrates the derivative integrand once per parameter
#: component; past this many components the quadratic cost stops being a
#: side channel and the executor skips the sdev pass (the gradients
#: themselves still come from ONE vjp regardless of component count).
MAX_SDEV_COMPONENTS = 16


@dataclasses.dataclass(frozen=True)
class GradProgram:
    """The three phases of one differentiable run, as separable callables.

    ``adapt(params, lower, upper, key) -> (edges, n_h, it)`` — the frozen
    map (all outputs gradient-stopped); ``value(params, lower, upper, edges,
    n_h, ekey)`` — the primal ``(mean, sigma2)`` on the plan's backend;
    ``diff(...)`` — same signature and estimator, but pure-jnp (``ref``
    fill, mode-wrapped integrand, bounds-rescaled edges): THE function whose
    VJP/JVP is the gradient.  ``pair``/``pair_jvp`` assemble them behind a
    `jax.custom_vjp`/`jax.custom_jvp` boundary with signature ``(params,
    lower, upper, key) -> (mean, sigma2)`` — differentiable in the first
    three, the key's cotangent is ``None``.
    """
    adapt: callable
    value: callable
    diff: callable
    pair: callable
    pair_jvp: callable
    mode: str


def _make_program(plan, fn, name: str) -> GradProgram:
    """Build the two-phase program for ``fn(params, x)`` under a grad plan."""
    rcfg, mode = plan.cfg, plan.grad.mode
    backend_fill = backends_mod.bind_fill(rcfg, backend=plan.backend.name)
    ref_fill = backends_mod.bind_fill(rcfg, backend="ref")

    def integrand(params, lower, upper, wrapped=False):
        m = mode if wrapped else "pathwise"  # raw value either way
        return Integrand(name, rcfg.dim,
                         lambda x: mode_value(fn, params, x, m), lower, upper)

    def adapt(params, lower, upper, key):
        sg = jax.lax.stop_gradient
        p0 = jax.tree.map(sg, params)
        ig = integrand(p0, sg(lower), sg(upper))
        st = core.init_state(ig, rcfg, key)
        st = core.adapt_loop(st, ig, rcfg, 0, fill_fn=backend_fill,
                             stop=plan.stop)
        return sg(st.edges), sg(st.n_h), st.it

    def value(params, lower, upper, edges, n_h, ekey):
        ig = integrand(params, lower, upper)
        return core.eval_phase(edges, n_h, ig, rcfg, ekey,
                               fill_fn=backend_fill)

    def diff(params, lower, upper, edges0, n_h, ekey):
        edges = rescale_edges(edges0, lower, upper)
        ig = integrand(params, lower, upper, wrapped=True)
        return core.eval_phase(edges, n_h, ig, rcfg, ekey, fill_fn=ref_fill)

    @jax.custom_vjp
    def pair(params, lower, upper, key):
        edges, n_h, _ = adapt(params, lower, upper, key)
        return value(params, lower, upper, edges, n_h,
                     core.eval_key(key, rcfg))

    def pair_fwd(params, lower, upper, key):
        edges, n_h, _ = adapt(params, lower, upper, key)
        ekey = core.eval_key(key, rcfg)
        out = value(params, lower, upper, edges, n_h, ekey)
        return out, (params, lower, upper, edges, n_h, ekey)

    def pair_bwd(residuals, ct):
        params, lower, upper, edges, n_h, ekey = residuals
        _, vjp_fn = jax.vjp(
            lambda p, l, u: diff(p, l, u, edges, n_h, ekey),
            params, lower, upper)
        gp, gl, gu = vjp_fn(ct)
        return gp, gl, gu, None  # the PRNG key takes no cotangent

    pair.defvjp(pair_fwd, pair_bwd)

    @jax.custom_jvp
    def pair_jvp(params, lower, upper, key):
        edges, n_h, _ = adapt(params, lower, upper, key)
        return value(params, lower, upper, edges, n_h,
                     core.eval_key(key, rcfg))

    @pair_jvp.defjvp
    def pair_jvp_rule(primals, tangents):
        params, lower, upper, key = primals
        dp, dl, du, _ = tangents  # the key's tangent (float0) is unused
        edges, n_h, _ = adapt(params, lower, upper, key)
        ekey = core.eval_key(key, rcfg)
        out = value(params, lower, upper, edges, n_h, ekey)
        # Linear in (dp, dl, du) => jax.grad reaches THIS flavor too, by
        # transposing the jvp of the reference eval pass.
        _, dout = jax.jvp(lambda p, l, u: diff(p, l, u, edges, n_h, ekey),
                          (params, lower, upper), (dp, dl, du))
        return out, dout

    return GradProgram(adapt=adapt, value=value, diff=diff, pair=pair,
                       pair_jvp=pair_jvp, mode=mode)


def differentiable(fn, dim: int, lower, upper,
                   cfg: core.VegasConfig | None = None, *,
                   execution=None, ad: str = "vjp",
                   name: str = "integrand"):
    """A differentiable estimate of ``int fn(params, x) dx`` over a box.

    Returns ``est(params, key, lower=None, upper=None) -> mean`` — a jittable,
    vmappable scalar function differentiable w.r.t. ``params`` (any pytree)
    and the bounds; ``est.pair`` exposes ``(params, lower, upper, key) ->
    (mean, sigma2)`` and ``est.plan`` the validated plan.  ``ad`` selects the
    custom-AD flavor (``'vjp'`` default; ``'jvp'`` for forward-mode
    consumers — ``jax.grad`` works through either).

    Plan validation runs up front: if ``execution`` carries no active
    `GradPolicy` a default pathwise one is attached, so e.g. a
    ``pallas-fused`` backend is rejected here with the §11 `PlanError`, not
    by a tracer error at grad time.
    """
    if ad not in ("vjp", "jvp"):
        raise ValueError(f"ad={ad!r} is not one of ('vjp', 'jvp')")
    from repro.engine import ExecutionConfig, make_plan
    cfg = cfg or core.VegasConfig()
    execution = execution or cfg.execution or ExecutionConfig()
    if execution.grad is None or not execution.grad.active:
        execution = dataclasses.replace(execution, grad=GradPolicy())
    lower_t, upper_t = tuple(map(float, lower)), tuple(map(float, upper))
    probe = Integrand(name, dim, lambda x: jnp.zeros(x.shape[:-1]),
                      lower_t, upper_t)
    plan = make_plan(probe, cfg, execution=execution)

    prog = _make_program(plan, fn, name)
    pair = prog.pair if ad == "vjp" else prog.pair_jvp
    dt = jnp.dtype(plan.cfg.dtype)
    l0 = jnp.asarray(lower_t, dt)
    u0 = jnp.asarray(upper_t, dt)

    def est(params, key, lower=None, upper=None):
        l = l0 if lower is None else jnp.asarray(lower, dt)
        u = u0 if upper is None else jnp.asarray(upper, dt)
        return pair(params, l, u, key)[0]

    est.pair = pair
    est.program = prog
    est.plan = plan
    return est


# --- executor entry ----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GradResult:
    """A single-scenario differentiable run: the estimate plus its boundary
    sensitivities ``d(mean)/d(lower_j)``, ``d(mean)/d(upper_j)``."""
    mean: float
    sdev: float
    grad_lower: np.ndarray   # (d,)
    grad_upper: np.ndarray   # (d,)
    n_it_used: int
    mode: str

    def __repr__(self):
        return (f"GradResult(mean={self.mean:.8g}, sdev={self.sdev:.3g}, "
                f"mode={self.mode}, n_it_used={self.n_it_used})")


@dataclasses.dataclass(frozen=True)
class BatchGradResult:
    """A family grad run: per-scenario estimates and parameter gradients.

    ``grad`` mirrors ``family.params`` (every leaf keeps its leading batch
    axis); ``grad_sdev`` (same structure, or ``None`` when the policy or the
    component cap disabled it) is each gradient component's own Monte Carlo
    standard error from the derivative-integrand pass."""
    mean: np.ndarray         # (B,)
    sdev: np.ndarray         # (B,)
    grad: object             # pytree like family.params
    grad_sdev: object        # pytree like family.params, or None
    n_it_used: np.ndarray    # (B,)
    mode: str

    @property
    def batch_size(self) -> int:
        return self.mean.shape[0]

    def __repr__(self):
        lines = [f"BatchGradResult(B={self.batch_size}, mode={self.mode}, "
                 f"with_sdev={self.grad_sdev is not None})"]
        flat = jax.tree.leaves(self.grad)
        for b in range(self.batch_size):
            g = ", ".join(f"{np.asarray(leaf[b]).ravel()[0]:+.4g}"
                          for leaf in flat)
            lines.append(f"  [{b}] {self.mean[b]:.8g} +- {self.sdev[b]:.3g} "
                         f"grad=[{g}]")
        return "\n".join(lines)


def execute_grad(plan, key):
    """Run a grad plan: the executor's §11 route (``plan.grad`` active).

    Single `Integrand` workloads return :class:`GradResult` with boundary
    sensitivities; `IntegrandFamily` workloads return
    :class:`BatchGradResult` with the whole two-phase program — adapt, eval,
    VJP, and the optional per-component sdev passes — ``vmap``-ped over the
    scenario axis as one jitted program (scenario ``b`` streams from
    ``fold_in(key, b)``, matching the non-grad batch engine)."""
    if plan.is_family:
        return _execute_grad_family(plan, key)
    return _execute_grad_single(plan, key)


def _execute_grad_single(plan, key):
    ig, rcfg = plan.workload, plan.cfg
    dt = jnp.dtype(rcfg.dtype)
    prog = _make_program(plan, lambda _p, x: ig.fn(x), ig.name)
    l0, u0 = jnp.asarray(ig.lower, dt), jnp.asarray(ig.upper, dt)

    def go(key):
        p = jnp.zeros((), dt)  # a plain integrand carries no parameters
        edges, n_h, it = prog.adapt(p, l0, u0, key)
        ekey = core.eval_key(key, rcfg)
        mean, sigma2 = prog.value(p, l0, u0, edges, n_h, ekey)
        _, vjp_fn = jax.vjp(
            lambda l, u: prog.diff(p, l, u, edges, n_h, ekey), l0, u0)
        gl, gu = vjp_fn((jnp.ones_like(mean), jnp.zeros_like(sigma2)))
        return mean, sigma2, gl, gu, it

    mean, sigma2, gl, gu, it = jax.jit(go)(key)
    return GradResult(float(mean), float(jnp.sqrt(sigma2)),
                      np.asarray(gl), np.asarray(gu), int(it), prog.mode)


def _execute_grad_family(plan, key):
    from repro.batch.engine import scenario_keys
    family, rcfg, policy = plan.workload, plan.cfg, plan.grad
    dt = jnp.dtype(rcfg.dtype)
    prog = _make_program(plan, family.fn, family.name)
    ref_fill = backends_mod.bind_fill(rcfg, backend="ref")
    l0 = jnp.asarray(family.lower, dt)
    u0 = jnp.asarray(family.upper, dt)

    p_ex = jax.tree.map(lambda leaf: leaf[0], family.params)
    flat_ex, unravel = jax.flatten_util.ravel_pytree(p_ex)
    n_comp = flat_ex.size
    with_sdev = policy.with_sdev and n_comp <= MAX_SDEV_COMPONENTS

    def one(p_b, key_b):
        edges, n_h, it = prog.adapt(p_b, l0, u0, key_b)
        ekey = core.eval_key(key_b, rcfg)
        mean, sigma2 = prog.value(p_b, l0, u0, edges, n_h, ekey)
        _, vjp_fn = jax.vjp(
            lambda p: prog.diff(p, l0, u0, edges, n_h, ekey), p_b)
        (gp,) = vjp_fn((jnp.ones_like(mean), jnp.zeros_like(sigma2)))
        if not with_sdev:
            return mean, sigma2, gp, it, jnp.zeros((n_comp,), dt)
        flat_b, unravel_b = jax.flatten_util.ravel_pytree(p_b)
        gs2 = []
        for i in range(n_comp):  # static per-component loop (n_comp small)
            tv = unravel_b(jnp.zeros_like(flat_b).at[i].set(1.0))
            _, gs2_i = directional_moments(
                family.fn, p_b, tv, l0, u0, edges, n_h, ekey, rcfg,
                ref_fill, prog.mode)
            gs2.append(gs2_i)
        return mean, sigma2, gp, it, jnp.stack(gs2).astype(dt)

    keys = scenario_keys(key, family.batch_size)
    mean, sigma2, gp, it, gs2 = jax.jit(jax.vmap(one))(family.params, keys)

    grad = jax.tree.map(np.asarray, gp)
    grad_sdev = None
    if with_sdev:
        per = jax.vmap(lambda row: unravel(jnp.sqrt(row)))(gs2)
        grad_sdev = jax.tree.map(np.asarray, per)
    return BatchGradResult(np.asarray(mean), np.asarray(jnp.sqrt(sigma2)),
                           grad, grad_sdev,
                           np.asarray(it, dtype=np.int64), prog.mode)
