"""Differentiable-estimator primitives for the two-phase grad run (§11).

The frozen-map evaluation pass (`core.eval_phase` over the ``ref`` backend)
is pure jnp — scan, scatter-add, log/exp jacobian — hence differentiable
w.r.t. anything the integrand closes over AND w.r.t. the map edges.  This
module supplies the three pieces `repro.grad.api` composes around it:

  * :func:`rescale_edges` — re-expresses the converged (frozen) map on
    traced integration bounds via an affine change of variables, so
    ``d(estimate)/d(lower, upper)`` flows through the map geometry while the
    map's *shape* stays ``stop_gradient``-anchored;
  * :func:`score_surrogate` — the score-function rewrite whose value equals
    the integrand but whose tangent is ``f · d(log f)`` (the log-derivative
    trick), for ``GradPolicy(mode='score')``;
  * :func:`directional_moments` — integrates the *derivative integrand*
    ``x -> d f(theta + eps v, x)/d eps`` through the same frozen-map pass,
    yielding both the directional gradient and its own Monte Carlo variance
    (the ``with_sdev`` uncertainty channel: a gradient estimate is itself a
    VEGAS integral, so it gets a sigma like any other).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fill as fill_mod
from repro.core.integrands import Integrand


def rescale_edges(edges0, lower, upper):
    """Affine change of variables: the frozen map re-anchored on traced bounds.

    ``edges0 (d, ninc+1)`` is a converged map whose endpoints are the
    *adapt-time* bounds ``(l0, u0)`` (read off the map itself and
    ``stop_gradient``-ed, so the anchor carries no tangent).  Each row is
    mapped through ``t = (e - l0) / (u0 - l0)``, ``e' = lower + (upper -
    lower) * t`` — endpoints land exactly on the traced bounds, interior
    knots keep their relative positions, and the per-interval jacobian
    scales by ``(upper - lower) / (u0 - l0) > 0`` uniformly.

    Evaluated at ``lower == l0, upper == u0`` the rescale is a value-level
    identity (up to one rounding), so the backward pass linearizes at the
    same map the primal used; its derivative gives the exact boundary
    sensitivity — for a constant integrand ``c``, ``estimate = c·prod(upper
    - lower)`` and ``d(est)/d(upper_j) = est / (upper_j - lower_j)``
    (tests/test_grad_properties.py holds this identity to float precision).
    """
    sg = jax.lax.stop_gradient
    e0 = sg(edges0)
    l0, u0 = e0[:, :1], e0[:, -1:]
    t = (e0 - l0) / (u0 - l0)
    return lower[:, None] + (upper - lower)[:, None] * t


def score_surrogate(f, tiny: float = 1e-30):
    """Log-derivative surrogate: value ``== f``, tangent ``== f · d(log f)``.

    ``stop_gradient(f) * (1 + log f - stop_gradient(log f))`` — the standard
    score-function identity ``f · d(log f) = df`` means the surrogate's
    gradient EQUALS the pathwise one wherever ``f > tiny``; where ``f <=
    tiny`` (the clamp's flat region — e.g. an option payoff's out-of-the-
    money samples) the tangent is exactly zero.  The point of the mode is
    the *form*: only ``log f``'s derivative is consumed, which is what a
    log-space integrand (Bayesian-evidence workloads) can supply without
    ever exponentiating its tangent.
    """
    sg = jax.lax.stop_gradient
    logf = jnp.log(jnp.maximum(f, tiny))
    return sg(f) * (1.0 + logf - sg(logf))


def mode_value(fn, params, x, mode: str):
    """The eval-pass integrand under a grad mode: raw ``fn`` for
    ``pathwise``, the score surrogate for ``score`` (same value either way —
    the modes differ only in tangent)."""
    f = fn(params, x)
    return score_surrogate(f) if mode == "score" else f


def directional_moments(fn, params, tangent, lower, upper, edges, n_h, ekey,
                        rcfg, ref_fill, mode: str = "pathwise"):
    """Frozen-map moments of the derivative integrand along ``tangent``.

    Builds ``dfn(x) = d/d eps [mode_value(fn, params + eps·tangent, x)]`` via
    ``jax.jvp`` and runs ONE reference fill of it over the same frozen
    ``(edges, n_h)`` and the same eval key as the value pass.  Returns
    ``(g, g_sigma2)`` from :func:`fill.estimate_from_cubes`: ``g`` is the
    directional gradient (it matches the VJP of the eval pass contracted
    with ``tangent``, same sample paths), ``g_sigma2`` its Monte Carlo
    variance — the ``GradPolicy(with_sdev=True)`` error bar.
    """
    def dfn(x):
        return jax.jvp(lambda p: mode_value(fn, p, x, mode),
                       (params,), (tangent,))[1]

    ig = Integrand("d_" + str(getattr(fn, "__name__", "integrand")),
                   rcfg.dim, dfn, lower, upper)
    res = ref_fill(edges, n_h, ekey, ig)
    g, g_sigma2, _ = fill_mod.estimate_from_cubes(res, n_h)
    return g, g_sigma2
