"""Warm-start map cache: amortize adaptation across sweeps (DESIGN.md B3).

The expensive part of a VEGAS+ run is the early iterations that mold the
importance map; the map itself is O(d·ninc) and mesh-free.  A sweep service
that repeatedly integrates the same family (new strikes, more precision,
fresh seeds) can therefore skip the cold start: cache the converged
``VegasState.edges`` keyed by (family, resolved config) and seed the next
batch run with them — the serving-style amortization the batch engine's
``cache=`` argument wires in.

Storage is an in-memory dict with optional ``.npz`` persistence (same
plain-numpy-inspectable philosophy as ``dist.checkpoint``).  Entries are
per-scenario ``(B, d, ninc+1)`` arrays; the key pins family name, batch
size, and every config field that changes map geometry or adaptation, so a
hit is always shape- and semantics-compatible.
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np


def cache_key(family, rcfg) -> str:
    """Cache key pinning family identity + map-relevant config fields."""
    return (f"{family.name}.B{family.batch_size}.d{rcfg.dim}"
            f".ninc{rcfg.ninc}.ns{rcfg.nstrat}.a{rcfg.alpha}.b{rcfg.beta}")


class MapCache:
    """In-memory map cache with optional on-disk ``.npz`` persistence."""

    def __init__(self, path: str | None = None):
        self.path = path
        self._mem: dict[str, np.ndarray] = {}
        if path is not None and os.path.exists(path):
            with np.load(path) as z:
                self._mem = {k: z[k] for k in z.files}

    def __len__(self) -> int:
        return len(self._mem)

    def get(self, family, rcfg):
        """Cached converged edges ``(B, d, ninc+1)`` for this (family,
        config), or ``None`` on a miss."""
        arr = self._mem.get(cache_key(family, rcfg))
        if arr is None:
            return None
        return jnp.asarray(arr, jnp.dtype(rcfg.dtype))

    def put(self, family, rcfg, edges) -> None:
        """Store converged edges (any array-like ``(B, d, ninc+1)``)."""
        arr = np.asarray(edges)
        expected = (family.batch_size, rcfg.dim, rcfg.ninc + 1)
        assert arr.shape == expected, (arr.shape, expected)
        self._mem[cache_key(family, rcfg)] = arr
        if self.path is not None:
            self._flush()

    def _flush(self) -> None:
        # Atomic write, same pattern as dist.checkpoint: complete or absent.
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **self._mem)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
