"""Warm-start map cache: amortize adaptation across sweeps (DESIGN.md B3).

The expensive part of a VEGAS+ run is the early iterations that mold the
importance map; the map itself is O(d·ninc) and mesh-free.  A sweep service
that repeatedly integrates the same family (new strikes, more precision,
fresh seeds) can therefore skip the cold start: cache the converged
``VegasState.edges`` keyed by (family, resolved config) and seed the next
batch run with them — the serving-style amortization the batch engine's
``cache=`` argument wires in.

Storage is an in-memory dict with optional ``.npz`` persistence (same
plain-numpy-inspectable philosophy as ``dist.checkpoint``).  Entries are
per-scenario ``(B, d, ninc+1)`` arrays; the key pins family name, batch
size, accumulation dtype, and every config field that changes map geometry
or adaptation, so a hit is always shape- and semantics-compatible.

Multi-writer safety: several processes (a sweep service and a CLI sweep,
or two services) may share one cache path.  Each flush RELOADS the on-disk
file and merges it with this writer's own entries before the atomic
``os.replace`` — a writer can only ever overwrite the keys it itself wrote,
never silently drop another writer's entries (the lost-update bug the
init-snapshot rewrite had).  Last-writer-wins per key is the intended
semantics; the window between reload and replace is not locked, so two
simultaneous flushes of the SAME key race benignly (either converged map is
a valid warm start).
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np


def cache_key(family, rcfg) -> str:
    """Cache key pinning family identity + map-relevant config fields.

    ``dtype`` is part of the key: edges adapted under f64 accumulation are
    not the same map as the f32 run's (different rounding all the way down
    the adaptation), and before the pin a ``get()`` would silently cast a
    stored f64 map into an f32 plan (and vice versa).  A widened §15
    PrecisionPolicy changes the adaptation statistics the same way, so a
    non-default ``accum_dtype`` joins the key (the suffix appears only when
    widened — pre-§15 cache files keep hitting for default-policy runs).
    """
    key = (f"{family.name}.B{family.batch_size}.d{rcfg.dim}"
           f".ninc{rcfg.ninc}.ns{rcfg.nstrat}.a{rcfg.alpha}.b{rcfg.beta}"
           f".dt{jnp.dtype(rcfg.dtype).name}")
    prec = getattr(getattr(rcfg, "execution", None), "precision", None)
    if prec is not None and prec.accum_dtype is not None \
            and jnp.dtype(prec.accum_dtype) != jnp.dtype(rcfg.dtype):
        key += f".acc{jnp.dtype(prec.accum_dtype).name}"
    return key


class MapCache:
    """In-memory map cache with optional on-disk ``.npz`` persistence."""

    def __init__(self, path: str | None = None):
        self.path = path
        self._mem: dict[str, np.ndarray] = {}
        self._dirty: set[str] = set()  # keys THIS writer wrote since flush
        if path is not None and os.path.exists(path):
            self._mem = self._load_disk()

    def __len__(self) -> int:
        return len(self._mem)

    def get(self, family, rcfg):
        """Cached converged edges ``(B, d, ninc+1)`` for this (family,
        config), or ``None`` on a miss."""
        arr = self._mem.get(cache_key(family, rcfg))
        if arr is None:
            return None
        return jnp.asarray(arr, jnp.dtype(rcfg.dtype))

    def put(self, family, rcfg, edges) -> None:
        """Store converged edges (any array-like ``(B, d, ninc+1)``)."""
        arr = np.asarray(edges)
        expected = (family.batch_size, rcfg.dim, rcfg.ninc + 1)
        assert arr.shape == expected, (arr.shape, expected)
        key = cache_key(family, rcfg)
        self._mem[key] = arr
        self._dirty.add(key)
        if self.path is not None:
            self._flush()

    def _load_disk(self) -> dict[str, np.ndarray]:
        try:
            with np.load(self.path) as z:
                return {k: z[k] for k in z.files}
        except Exception:
            # os.replace keeps the file complete-or-absent; an unreadable
            # file means external corruption — start from empty rather than
            # refuse every flush forever.
            return {}

    def _flush(self) -> None:
        # Reload-and-merge: concurrent writers sharing this path may have
        # added entries since our init snapshot — take the disk state as
        # the base and overlay only the keys WE wrote, so their entries
        # survive our flush (and their fresher value of a key we did not
        # touch wins over our stale snapshot).
        disk = self._load_disk() if os.path.exists(self.path) else {}
        disk.update({k: self._mem[k] for k in self._dirty})
        self._mem = disk
        # Atomic write, same pattern as dist.checkpoint: complete or absent.
        # The tmp name is per-process so two concurrent flushes never
        # interleave bytes in one staging file.
        tmp = f"{self.path}.{os.getpid()}.tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **self._mem)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        self._dirty.clear()
