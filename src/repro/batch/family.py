"""Parameterized integrand families: the batch axis of the batched engine.

An :class:`IntegrandFamily` is a single traced callable ``fn(params, x)``
plus a pytree of per-scenario parameters whose leaves carry a leading batch
axis ``B``.  The engine ``vmap``s the whole VEGAS+ iteration loop over that
axis (DESIGN.md B2), so B scenarios — e.g. Gaussian peaks at B locations, an
Asian option at B strikes, B ridge orientations — adapt and integrate
concurrently inside one XLA program.

Bounds are shared across the batch (they fix the static map geometry); only
``params`` varies per scenario.  ``instance(b)`` materializes scenario ``b``
as a plain :class:`~repro.core.integrands.Integrand` for serial comparison
runs and tests.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.integrands import Integrand


@dataclasses.dataclass(frozen=True)
class IntegrandFamily:
    name: str
    dim: int
    fn: Callable[[Any, jax.Array], jax.Array]  # fn(params, x (n,d)) -> (n,)
    lower: tuple
    upper: tuple
    params: Any                      # pytree; every leaf has leading axis B
    targets: np.ndarray | None = None  # (B,) analytic values where known

    @property
    def batch_size(self) -> int:
        return jax.tree.leaves(self.params)[0].shape[0]

    def bind(self, params) -> Integrand:
        """Close over one (possibly traced) parameter slice — the integrand
        the vmapped loop evaluates."""
        return Integrand(self.name, self.dim, lambda x: self.fn(params, x),
                         self.lower, self.upper)

    def instance(self, b: int) -> Integrand:
        """Scenario ``b`` as a standalone Integrand (serial runs, tests)."""
        p = jax.tree.map(lambda leaf: leaf[b], self.params)
        target = float(self.targets[b]) if self.targets is not None else None
        return Integrand(f"{self.name}[{b}]", self.dim,
                         lambda x: self.fn(p, x), self.lower, self.upper,
                         target)


# --- Concrete families --------------------------------------------------------

def make_gaussian_family(mus, dim: int = 4, sigma: float = 0.1) -> IntegrandFamily:
    """Product Gaussians peaked at per-scenario locations ``mus (B,)`` (the
    paper's Table 3 #7 with the peak swept across the unit cube)."""
    mus = np.asarray(mus, np.float64)
    norm = 1.0 / (2.0 * math.pi * sigma**2) ** (dim / 2.0)

    def fn(mu, x):
        return norm * jnp.exp(-jnp.sum((x - mu) ** 2, axis=-1) / (2.0 * sigma**2))

    targets = np.array([
        (math.erf((1.0 - m) / (sigma * math.sqrt(2.0))) / 2.0
         + math.erf(m / (sigma * math.sqrt(2.0))) / 2.0) ** dim
        for m in mus])
    return IntegrandFamily("gaussian_family", dim, fn, (0.0,) * dim,
                           (1.0,) * dim, jnp.asarray(mus, jnp.float32), targets)


def make_asian_family(strikes, n_steps: int = 8, s0: float = 100.0,
                      r: float = 0.1, sigma: float = 0.2, t_mat: float = 1.0,
                      geometric: bool = True) -> IntegrandFamily:
    """Asian call (paper eq. (10)-(11)) at per-scenario strikes ``(B,)`` —
    the serving-shaped workload: one adapted map family, many contracts.
    The geometric variant has a closed form used as the target."""
    strikes = np.asarray(strikes, np.float64)
    dt = t_mat / n_steps
    drift = (r - 0.5 * sigma**2) * dt
    vol = sigma * math.sqrt(dt)

    def fn(strike, x):
        eps = 1e-6 if x.dtype == jnp.float32 else 1e-12
        xc = jnp.clip(x, eps, 1.0 - eps)
        z = jax.scipy.special.erfinv(2.0 * xc - 1.0) * math.sqrt(2.0)
        logpath = jnp.cumsum(drift + vol * z, axis=-1)
        if geometric:
            avg = s0 * jnp.exp(jnp.mean(logpath, axis=-1))
        else:
            avg = jnp.mean(s0 * jnp.exp(logpath), axis=-1)
        return math.exp(-r * t_mat) * jnp.maximum(avg - strike, 0.0)

    targets = None
    if geometric:
        from repro.core.targets import asian_geometric_closed_form
        targets = np.array([asian_geometric_closed_form(s0, k, r, sigma,
                                                        t_mat, n_steps)
                            for k in strikes])
    name = "asian_geo_family" if geometric else "asian_family"
    return IntegrandFamily(name, n_steps, fn, (0.0,) * n_steps,
                           (1.0,) * n_steps,
                           jnp.asarray(strikes, jnp.float32), targets)


def make_asian_greeks_family(strikes, sigmas=None, n_steps: int = 8,
                             s0: float = 100.0, r: float = 0.1,
                             t_mat: float = 1.0) -> IntegrandFamily:
    """Geometric Asian call with per-scenario ``{'strike', 'sigma'}`` params
    — the Greeks workload of the differentiable engine (`repro.grad`, §11).

    Where `make_asian_family` bakes the volatility into the closure (a
    static float the tracer never sees), here BOTH contract parameters ride
    the params pytree, so ``d(price)/d(strike)`` (dual delta) and
    ``d(price)/d(sigma)`` (vega) flow out of one vjp per scenario.  The
    drift/vol path coefficients are recomputed from the traced ``sigma``
    inside ``fn`` — that dependence IS the vega path.  Targets stay the
    geometric closed form, so grad tests can finite-difference an exact
    price curve rather than another Monte Carlo estimate.
    """
    strikes = np.asarray(strikes, np.float64)
    sigmas = (np.full_like(strikes, 0.2) if sigmas is None
              else np.broadcast_to(np.asarray(sigmas, np.float64),
                                   strikes.shape))
    dt = t_mat / n_steps

    def fn(params, x):
        strike, sigma = params["strike"], params["sigma"]
        drift = (r - 0.5 * sigma**2) * dt
        vol = sigma * math.sqrt(dt)
        eps = 1e-6 if x.dtype == jnp.float32 else 1e-12
        xc = jnp.clip(x, eps, 1.0 - eps)
        z = jax.scipy.special.erfinv(2.0 * xc - 1.0) * math.sqrt(2.0)
        logpath = jnp.cumsum(drift + vol * z, axis=-1)
        avg = s0 * jnp.exp(jnp.mean(logpath, axis=-1))
        return math.exp(-r * t_mat) * jnp.maximum(avg - strike, 0.0)

    from repro.core.targets import asian_geometric_closed_form
    targets = np.array([asian_geometric_closed_form(s0, k, r, sig, t_mat,
                                                    n_steps)
                        for k, sig in zip(strikes, sigmas)])
    params = {"strike": jnp.asarray(strikes, jnp.float32),
              "sigma": jnp.asarray(sigmas, jnp.float32)}
    return IntegrandFamily("asian_greeks_family", n_steps, fn,
                           (0.0,) * n_steps, (1.0,) * n_steps, params,
                           targets)


def make_ridge_family(directions, dim: int = 4, n_peaks: int = 50) -> IntegrandFamily:
    """Ridge integrand (Table 3 #8) with per-scenario peak-line orientation.

    ``directions (B, dim)`` with components in (0, 1]: scenario b places its
    ``n_peaks`` Gaussians at ``c_i * directions[b]`` for ``c_i`` on a uniform
    grid in [0, 1] — direction (1,...,1) recovers the paper's main-diagonal
    ridge.  The target factorizes per dimension (erf closed form), so every
    orientation keeps an analytic value.
    """
    directions = np.asarray(directions, np.float64)
    assert directions.shape[1] == dim, (directions.shape, dim)
    centers = np.linspace(0.0, 1.0, n_peaks)
    scale = 10000.0 / (math.pi**2 * n_peaks)
    cj = jnp.asarray(centers, jnp.float32)

    def fn(v, x):
        # (n, 1, d) - (P, d) peak grid along direction v.
        peaks = cj[:, None] * v[None, :]
        d2 = jnp.sum((x[:, None, :] - peaks[None, :, :]) ** 2, axis=-1)
        return scale * jnp.sum(jnp.exp(-100.0 * d2), axis=-1)

    from scipy.special import erf
    # per-(peak, dim) marginal: int_0^1 exp(-100 (x - c v_j)^2) dx
    cv = centers[:, None] * directions[:, None, :]          # (B, P, d)
    per = (math.sqrt(math.pi) / 20.0) * (erf(10.0 * (1.0 - cv)) + erf(10.0 * cv))
    targets = scale * np.sum(np.prod(per, axis=-1), axis=-1)  # (B,)
    return IntegrandFamily("ridge_family", dim, fn, (0.0,) * dim,
                           (1.0,) * dim,
                           jnp.asarray(directions, jnp.float32), targets)


FAMILIES = {
    "gaussian": lambda b: make_gaussian_family(np.linspace(0.2, 0.8, b)),
    "asian": lambda b: make_asian_family(np.linspace(80.0, 120.0, b)),
    "asian_greeks": lambda b: make_asian_greeks_family(
        np.linspace(80.0, 120.0, b), np.linspace(0.15, 0.3, b)),
    "ridge": lambda b: make_ridge_family(
        0.5 + 0.5 * (np.arange(b)[:, None] * np.arange(1, 5)[None, :] % 7) / 7.0),
}
