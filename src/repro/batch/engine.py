"""Batched multi-integrand engine: one XLA program, B scenarios.

``run_batch`` is a thin adapter over the unified execution engine
(`repro.engine`, DESIGN.md §9): it plans the family workload on the vmap
batch axis and executes the whole iteration loop (`core.run_loop`, B1/B2)
as ONE jitted program — B parameterized integrands draw, adapt their
importance maps, re-allocate their stratifications, and aggregate
concurrently, with zero host round-trips.  Compose with the other plan axes
through ``ExecutionConfig``: a pallas backend, a device mesh (sharded fill
per scenario — B integrands × D devices in one program), a warm-start map
cache.

Per-scenario RNG: scenario ``b`` runs from ``fold_in(key, b)``, so its
stream is exactly what a serial ``core.run(family.instance(b), cfg,
key=fold_in(key, b))`` would draw — batched and serial results agree to
vmap-layout numerics (tests/test_batch.py checks 3 combined sigma).

Warm start: pass a ``cache.MapCache`` to seed every scenario's map with the
previously converged edges for this (family, config) and to store the new
converged maps after the run (B3).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import integrator as core
from .cache import MapCache
from .family import IntegrandFamily


@dataclasses.dataclass(frozen=True)
class BatchResult:
    """Per-scenario results of a batched run (arrays of leading dim B)."""
    mean: np.ndarray        # (B,)
    sdev: np.ndarray        # (B,)
    chi2_dof: np.ndarray    # (B,)
    n_used: np.ndarray      # (B,) iterations entering each combination
    n_it_used: np.ndarray   # (B,) iterations actually executed per scenario
                            # (< max_it where a StopPolicy converged, §10)
    iter_means: np.ndarray  # (B, max_it)
    iter_sdevs: np.ndarray  # (B, max_it); slots >= n_it_used[b] hold the
                            # inf sentinel of never-executed iterations
    states: core.VegasState  # batched pytree: every leaf has leading dim B
    warm_started: bool = False

    @property
    def batch_size(self) -> int:
        return self.mean.shape[0]

    def __repr__(self):
        lines = [f"BatchResult(B={self.batch_size}, "
                 f"warm_started={self.warm_started})"]
        for b in range(self.batch_size):
            lines.append(f"  [{b}] {self.mean[b]:.8g} +- {self.sdev[b]:.3g} "
                         f"(chi2/dof {self.chi2_dof[b]:.2f}, "
                         f"it {self.n_it_used[b]})")
        return "\n".join(lines)


def scenario_keys(key, batch_size: int) -> jax.Array:
    """Independent per-scenario base keys: ``fold_in(key, b)`` (the batch
    analogue of the chunk-keyed RNG contract, DESIGN.md C5)."""
    return jax.vmap(lambda b: jax.random.fold_in(key, b))(
        jnp.arange(batch_size))


def run_batch(family: IntegrandFamily, cfg: core.VegasConfig | None = None, *,
              key=None, cache: MapCache | None = None,
              execution=None) -> BatchResult:
    """Integrate all B scenarios of ``family`` in one jitted program.

    The per-iteration estimates, adaptation, and the final inverse-variance
    combination all happen on device; the host sees only the O(B·KB) result
    pytree once, after the loop.  ``cache`` (optional) warm-starts every
    scenario's importance map from the last converged run of the same
    (family, config) and refreshes the cache afterwards.  ``execution``
    (optional `repro.engine.ExecutionConfig`) overrides the config's
    execution axes — e.g. ``ExecutionConfig(backend='pallas-fused',
    mesh=make_local_mesh())`` runs the sharded batched program.
    """
    from repro.engine import execute, make_plan
    plan = make_plan(family, cfg, execution=execution)
    if not plan.batched:
        raise ValueError(
            "run_batch is the vmapped path; the plan resolved to "
            "batch='serial' — call run_serial (or repro.engine.execute) "
            "instead")
    return execute(plan, key=key, cache=cache)


def run_serial(family: IntegrandFamily, cfg: core.VegasConfig | None = None, *,
               key=None, execution=None) -> list[core.VegasResult]:
    """The B scenarios as B independent single-scenario runs — the baseline
    the batched engine is measured against (same per-scenario keys:
    ``fold_in(key, b)``).  Thin adapter over the engine's ``batch='serial'``
    plan, so both family paths share one validated implementation."""
    import dataclasses

    from repro.engine import execute, make_plan
    cfg = cfg or core.VegasConfig()
    execution = dataclasses.replace(execution or cfg.execution,
                                    batch="serial")
    return execute(make_plan(family, cfg, execution=execution), key=key)
