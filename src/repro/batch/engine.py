"""Batched multi-integrand engine: one XLA program, B scenarios.

``run_batch`` lifts the single-scenario on-device iteration loop
(``core.integrator.run_loop``, DESIGN.md B1) over the batch axis of an
:class:`~repro.batch.family.IntegrandFamily` with ``jax.vmap`` (B2): B
parameterized integrands draw, adapt their importance maps, re-allocate
their stratifications, and aggregate — concurrently, inside a single jitted
program with zero host round-trips.  This is the throughput shape the
ROADMAP's "as many scenarios as you can imagine" asks for: the accelerator
sees one big batched fill instead of B small sequential ones, so the
batched wall clock beats the serial loop (benchmarks/bench_batch.py).

Per-scenario RNG: scenario ``b`` runs from ``fold_in(key, b)``, so its
stream is exactly what a serial ``core.run(family.instance(b), cfg,
key=fold_in(key, b))`` would draw — batched and serial results agree to
vmap-layout numerics (tests/test_batch.py checks 3 combined sigma).

Warm start: pass a ``cache.MapCache`` to seed every scenario's map with the
previously converged edges for this (family, config) and to store the new
converged maps after the run (B3).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import integrator as core
from repro.core import map as vmap_
from .cache import MapCache
from .family import IntegrandFamily


@dataclasses.dataclass(frozen=True)
class BatchResult:
    """Per-scenario results of a batched run (arrays of leading dim B)."""
    mean: np.ndarray        # (B,)
    sdev: np.ndarray        # (B,)
    chi2_dof: np.ndarray    # (B,)
    n_used: np.ndarray      # (B,) iterations entering each combination
    iter_means: np.ndarray  # (B, max_it)
    iter_sdevs: np.ndarray  # (B, max_it)
    states: core.VegasState  # batched pytree: every leaf has leading dim B
    warm_started: bool = False

    @property
    def batch_size(self) -> int:
        return self.mean.shape[0]

    def __repr__(self):
        lines = [f"BatchResult(B={self.batch_size}, "
                 f"warm_started={self.warm_started})"]
        for b in range(self.batch_size):
            lines.append(f"  [{b}] {self.mean[b]:.8g} +- {self.sdev[b]:.3g} "
                         f"(chi2/dof {self.chi2_dof[b]:.2f})")
        return "\n".join(lines)


def scenario_keys(key, batch_size: int) -> jax.Array:
    """Independent per-scenario base keys: ``fold_in(key, b)`` (the batch
    analogue of the chunk-keyed RNG contract, DESIGN.md C5)."""
    return jax.vmap(lambda b: jax.random.fold_in(key, b))(
        jnp.arange(batch_size))


def _batched_program(family: IntegrandFamily, cfg: core.ResolvedConfig):
    """Build the jitted vmapped whole-run program for one family/config."""

    def one(params, key_b, edges0):
        ig = family.bind(params)
        st = core.init_state(ig, cfg, key_b)
        st = core.VegasState(edges0, st.n_h, st.key, st.it, st.results)
        st = core.run_loop(st, ig, cfg, 0)
        mean, sdev, chi2_dof, n_used = core.combine_results(
            st.results, cfg.skip, cfg.max_it)
        return st, mean, sdev, chi2_dof, n_used

    return jax.jit(jax.vmap(one))


def run_batch(family: IntegrandFamily, cfg: core.VegasConfig | None = None, *,
              key=None, cache: MapCache | None = None) -> BatchResult:
    """Integrate all B scenarios of ``family`` in one jitted program.

    The per-iteration estimates, adaptation, and the final inverse-variance
    combination all happen on device; the host sees only the O(B·KB) result
    pytree once, after the loop.  ``cache`` (optional) warm-starts every
    scenario's importance map from the last converged run of the same
    (family, config) and refreshes the cache afterwards.
    """
    rcfg = (cfg or core.VegasConfig()).resolve(family.dim)
    key = key if key is not None else jax.random.PRNGKey(0)
    b = family.batch_size

    edges0 = cache.get(family, rcfg) if cache is not None else None
    warm = edges0 is not None
    if edges0 is None:
        uni = vmap_.uniform_edges(family.lower, family.upper, rcfg.ninc,
                                  jnp.dtype(rcfg.dtype))
        edges0 = jnp.broadcast_to(uni, (b,) + uni.shape)

    prog = _batched_program(family, rcfg)
    states, mean, sdev, chi2_dof, n_used = prog(
        family.params, scenario_keys(key, b), edges0)

    if cache is not None:
        cache.put(family, rcfg, states.edges)

    sig2 = np.asarray(states.results[:, :, 1])
    return BatchResult(np.asarray(mean), np.asarray(sdev),
                       np.asarray(chi2_dof), np.asarray(n_used),
                       np.asarray(states.results[:, :, 0]), np.sqrt(sig2),
                       states, warm_started=warm)


def run_serial(family: IntegrandFamily, cfg: core.VegasConfig | None = None, *,
               key=None) -> list[core.VegasResult]:
    """The B scenarios as B independent ``core.run`` calls — the baseline the
    batched engine is measured against (same per-scenario keys)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    return [core.run(family.instance(b), cfg,
                     key=jax.random.fold_in(key, b))
            for b in range(family.batch_size)]
