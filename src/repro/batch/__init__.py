"""Batched multi-integrand engine: vmapped on-device VEGAS+ (DESIGN.md §6)."""

from .cache import MapCache  # noqa: F401
from .engine import BatchResult, run_batch, run_serial  # noqa: F401
from .family import (FAMILIES, IntegrandFamily,  # noqa: F401
                     make_asian_family, make_gaussian_family,
                     make_ridge_family)
