"""Pallas GPU (Triton-lowered) kernel for the VEGAS+ fill phase — the
``pallas-gpu`` backend of the engine registry (DESIGN.md §14).

Same contract as ``vegas_fill.vegas_fill_fused`` (the TPU/Mosaic P-V3
kernel), restructured for how a CUDA-class device actually wants the work
(m-Cubes arXiv:2202.01753 / PAGANI arXiv:2104.06494 — GPU integrators live
or die by how per-cube accumulation maps onto the memory hierarchy):

  * **grid over sample blocks, programs in PARALLEL** — the Mosaic grid is
    sequential, so the TPU kernel initializes its accumulators under
    ``@pl.when(i == 0)`` and accumulates with plain ``ref[...] +=``.  Triton
    programs race on both, so outputs here are **zero-initialized inputs
    aliased to outputs** (``input_output_aliases``) and every cross-program
    accumulation is a ``pl.atomic_add`` — cuVegas' own design (its D1
    deviation point: the CUDA kernel leans on atomics; the TPU port removed
    them, this backend puts them back where the hardware supports them).
  * **block-privatized histograms** — the canonical CUDA histogram idiom:
    each program reduces its ``block`` evaluations into a private partial
    histogram (a masked sum per bucket, held in registers/shared memory) and
    flushes ONE atomic add per bucket at in-call-unique indices.  Duplicate
    bucket hits therefore only ever collide ACROSS programs, where the
    atomics serialize them — never within one vectorized atomic call (whose
    semantics for duplicate indices are undefined-order, and which the
    interpreter resolves as last-write-wins).
  * **scatter/segment-sum cube accumulation** — the sorted cube ids advance
    by at most one per eval (every cube draws >= 2), so a block's ids span a
    window of <= ``block`` distinct slots starting at its first id; the
    per-window partial moments flush with one atomic add per slot into a
    flat ``(n_cubes + block,)`` accumulator (trimmed by the wrapper).  The
    TPU kernel's LANE-aligned one-hot *matmul* into a (rows, 128) VMEM
    accumulator only makes sense feeding an MXU — on GPU it would burn
    Tensor-Core shapes on what is fundamentally a scatter.
  * **gather loads, not one-hot matvecs** — map-table lookups are pointer
    gathers (``ew_ref[0, k*ninc + iy]``), the thing a GPU memory system is
    built for; the MXU gather-as-matmul trick is dropped.
  * **in-kernel threefry-2x32** — byte-identical to the TPU kernel's
    (``vegas_fill._tile_uniforms``): uniforms for global chunk ``g`` match
    ``jax.random.uniform(fold_in(key, g), (chunk, d))`` bit-for-bit under
    BOTH ``jax_threefry_partitionable`` layouts, so the existing parity and
    RNG-contract suites apply to this backend verbatim.

Knobs (declared in ``engine.backends`` like ``tile`` is for the TPU path):
``block`` — evaluations per program, the CUDA block-size analogue, default
from :func:`autotune_block` (largest power-of-two divisor of ``chunk``
within the shared-memory budget model); ``num_warps`` — forwarded to the
Triton compiler (``TritonCompilerParams``), harmless under interpret mode.

CI validates this kernel in interpret mode on CPU (the Pallas interpreter
runs the grid sequentially — atomics degenerate to plain adds, results are
deterministic); on a real GPU the compiled kernel's float atomics make
cube/map sums run-to-run nondeterministic at reduction-order level, the
same tradeoff cuVegas ships with (DESIGN.md §14).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import strat
from . import resolve_interpret
from . import vegas_fill as vk

_TINY = 1e-30

#: Shared-memory budget model for the ``block`` knob (bytes per program).
#: Ampere/Hopper parts carry 100-228 KB of shared memory per SM; 192 KB is
#: the documented planning budget (DESIGN.md §14) — generous enough that the
#: model constrains only genuinely oversized blocks, conservative enough
#: that one program's privatized histograms never spill to local memory.
SMEM_BUDGET = 192 << 10


def block_footprint_bytes(block: int, d: int, ninc: int, *,
                          accum_itemsize: int = 4) -> int:
    """Per-program scratch under the DESIGN.md §14/§15 budget math: the
    (block, ninc) masked partial behind the private map histogram and the
    (block, block) cube-window partial materialize at ``accum_itemsize``
    bytes (8 under a widened f64 policy — the where-products are widened
    BEFORE the masked sums so the privatized partials genuinely carry the
    accumulation dtype), plus ~8 f32 (block, d) transform temporaries.
    No grid-resident term: unlike the TPU kernel's VMEM accumulators, the
    full-size accumulators live in HBM behind atomics."""
    return (accum_itemsize * (block * ninc + block * block)
            + 4 * 8 * block * d)


def valid_blocks(chunk: int, d: int, ninc: int, *,
                 budget: int = SMEM_BUDGET,
                 max_block: int = 1024, accum_itemsize: int = 4) -> list[int]:
    """Every block size the kernel accepts for this shape, ascending:
    divisors of ``chunk`` whose :func:`block_footprint_bytes` fits the
    budget.  The single validity oracle shared by :func:`autotune_block` and
    the plan autotuner (`engine.autotune`) — mirroring ``ops.valid_tiles``
    so the tuner can never choose a block the kernel would reject.
    ``accum_itemsize`` prices the privatized partials (8 under an f64
    PrecisionPolicy, §15)."""
    return [b for b in range(1, min(chunk, max_block) + 1)
            if chunk % b == 0
            and block_footprint_bytes(b, d, ninc,
                                      accum_itemsize=accum_itemsize)
            <= budget]


def autotune_block(chunk: int, d: int, ninc: int, *,
                   budget: int = SMEM_BUDGET, max_block: int = 1024,
                   accum_itemsize: int = 4) -> int:
    """Largest power-of-two valid block (Triton tiles powers of two well;
    any valid divisor is accepted when no power of two fits)."""
    blocks = valid_blocks(chunk, d, ninc, budget=budget, max_block=max_block,
                          accum_itemsize=accum_itemsize)
    pow2 = [b for b in blocks if (b & (b - 1)) == 0]
    return (pow2 or blocks or [1])[-1]


def _pick_block(block: int | None, chunk: int, d: int, ninc: int,
                accum_itemsize: int = 4) -> int:
    if block is None:
        block = autotune_block(chunk, d, ninc,
                               accum_itemsize=accum_itemsize)
    else:
        block = min(block, chunk)
        if chunk % block != 0:
            # The grid is per-chunk, so the block must divide chunk: fall
            # back to the largest divisor below the request (same rule as
            # the TPU path's _pick_tile).
            block = next(b for b in range(block, 0, -1) if chunk % b == 0)
    if block < min(8, chunk):
        raise ValueError(
            f"chunk={chunk} has no usable block divisor <= {block}; "
            f"pick a chunk with a divisor >= 8 (or a block dividing it)")
    return block


def _fill_gpu_kernel(*refs, nstrat: int, n_cubes: int, ninc: int, chunk: int,
                     block: int, d: int, integrand, rng_in_kernel: bool,
                     accum_dtype=jnp.float32):
    rng_or_u_ref, cube_ref, ew_ref, *rest = refs
    const_refs = rest[:-4]
    ms_ref, mc_ref, s1_ref, s2_ref = rest[-4:]
    i = pl.program_id(0)
    dtype = jnp.float32
    cube = cube_ref[...]                        # (block,) int32, sorted

    if rng_in_kernel:
        # This program's slice of uniform(fold_in(key, g), (chunk, d)) —
        # the SAME threefry routine as the TPU kernel, bit-exact under both
        # jax_threefry_partitionable layouts.
        u = vk._tile_uniforms(rng_or_u_ref[0, 0], rng_or_u_ref[0, 1],
                              i * block, block, chunk, d)     # (block, d)
    else:
        u = rng_or_u_ref[...]                                 # (block, d)

    valid = cube < n_cubes                      # (block,)
    cube_c = jnp.minimum(cube, n_cubes - 1)

    # ---- transform: stratified decode -> map gather -> Jacobian ----
    x_cols = []
    iys = []
    logjac = jnp.zeros((block,), dtype)
    for k in range(d):
        c_k = (cube_c // (nstrat**k)) % nstrat                # (block,)
        y_k = (c_k.astype(dtype) + u[:, k]) / nstrat
        yn = y_k * ninc
        iy_k = jnp.clip(yn.astype(jnp.int32), 0, ninc - 1)    # (block,)
        frac = yn - iy_k.astype(dtype)
        # Pointer gathers from the interleaved flat tables — the GPU-native
        # replacement for the TPU kernel's one-hot gather matvecs.
        e_lo = ew_ref[0, k * ninc + iy_k]                     # (block,)
        dx = ew_ref[1, k * ninc + iy_k]                       # (block,)
        x_cols.append(e_lo + frac * dx)
        iys.append(iy_k)
        logjac = logjac + jnp.log(jnp.maximum(ninc * dx, _TINY))

    x = jnp.stack(x_cols, axis=1)                             # (block, d)
    jac = jnp.exp(logjac)                                     # (block,)

    fx = integrand(x, *[r[...] for r in const_refs])
    fx = fx.reshape(block).astype(dtype)
    w = jnp.where(valid, jac * fx, jnp.zeros((), dtype))      # (block,)
    # §15 widening boundary: transform + integrand products are f32; the
    # per-eval contributions widen HERE, before the privatized masked-sum
    # partials, so both the in-block reductions and the HBM atomic
    # accumulators run at accum_dtype (which the budget model prices).
    accum = jnp.dtype(accum_dtype)
    w = w.astype(accum)
    w2 = w * w
    cnt = valid.astype(accum)

    # ---- map histogram: block-private partials, one atomic per bucket ----
    lanes = jax.lax.broadcasted_iota(jnp.int32, (block, ninc), 1)
    for k in range(d):
        oh = iys[k][:, None] == lanes                         # (block, ninc)
        ms_k = jnp.sum(jnp.where(oh, w2[:, None], 0.0), axis=0)
        mc_k = jnp.sum(jnp.where(oh, cnt[:, None], 0.0), axis=0)
        idx = k * ninc + jax.lax.broadcasted_iota(jnp.int32, (ninc,), 0)
        # Indices are unique WITHIN this call (one per bucket); collisions
        # only happen across programs, which the atomics serialize.
        pl.atomic_add(ms_ref, (idx,), ms_k)
        pl.atomic_add(mc_ref, (idx,), mc_k)

    # ---- cube moments: windowed partials, one atomic per window slot ----
    # Sorted ids advance <= 1 per eval, so this block's ids live in
    # [cube_c[0], cube_c[0] + block); masked overflow evals clip into the
    # window but contribute exactly 0.
    base = cube_c[0]
    rel = jnp.clip(cube_c - base, 0, block - 1)               # (block,)
    wcols = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    ohc = rel[:, None] == wcols                               # (block, block)
    s1p = jnp.sum(jnp.where(ohc, w[:, None], 0.0), axis=0)    # (block,)
    s2p = jnp.sum(jnp.where(ohc, w2[:, None], 0.0), axis=0)
    cidx = base + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    pl.atomic_add(s1_ref, (cidx,), s1p)
    pl.atomic_add(s2_ref, (cidx,), s2p)


def vegas_fill_gpu(key_bits, cube, edges_lo, widths, *, nstrat: int,
                   n_cubes: int, integrand, block: int = 128,
                   interpret: bool = True, num_warps: int | None = None,
                   u=None, ig_consts=(), accum_dtype=None):
    """pallas_call wrapper for the Triton-shaped fill kernel (one chunk).

    Args:
      key_bits: (1, 2) uint32 raw key data of ``fold_in(key, gchunk)``.
      cube:     (chunk,) int32 SORTED cube ids; ``n_cubes`` == masked.
      edges_lo/widths: (d, ninc) f32 map tables.
      block:    evaluations per program (the CUDA block-size analogue);
                must divide ``chunk``.
      num_warps: Triton compiler knob (``TritonCompilerParams``); ignored
                by the interpreter, so interpret-mode CI exercises the same
                program the GPU compiles.
      u:        optional (chunk, d) f32 uniforms.  ``None`` generates them
                IN-KERNEL from ``key_bits``; passing the precomputed block
                is the interpret-mode escape hatch (same XLA:CPU threefry
                vectorization issue as the TPU path, DESIGN.md §7) —
                bit-identical either way.
      accum_dtype: accumulator dtype (default f32).  Under the §15 widened
                policy the four flat HBM accumulators are f64: per-eval
                products stay f32, each program widens its contributions
                before the privatized masked sums, and the atomic adds land
                on 8-byte slots.

    Returns flat ``(ms, mc, s1_pad, s2_pad)``: map moments as (d*ninc,) and
    cube moments as (n_cubes + block,) — reshape/trim in the caller.  All
    four are zero-initialized inputs aliased to outputs: the race-free init
    under a parallel grid (the TPU kernel's ``@pl.when(i == 0)`` writes
    would race here).
    """
    chunk = cube.shape[0]
    d, ninc = edges_lo.shape
    assert chunk % block == 0, (chunk, block)
    assert edges_lo.dtype == jnp.float32, \
        "pallas-gpu is f32-only (RNG contract)"
    accum = jnp.dtype(accum_dtype) if accum_dtype is not None else jnp.float32
    n_pad = n_cubes + block
    rng_in_kernel = u is None
    # Interleaved flat tables: row 0 = edges, row 1 = widths, each (d*ninc,)
    # so dimension k's interval j sits at flat index k*ninc + j.
    ew = jnp.stack([edges_lo.reshape(-1), widths.reshape(-1)])
    kig, flat_consts, const_specs = vk._const_transport(integrand, ig_consts)

    kernel = functools.partial(
        _fill_gpu_kernel, nstrat=nstrat, n_cubes=n_cubes, ninc=ninc,
        chunk=chunk, block=block, d=d, integrand=kig,
        rng_in_kernel=rng_in_kernel, accum_dtype=accum)
    grid = (chunk // block,)
    first_in = (key_bits, pl.BlockSpec((1, 2), lambda i: (0, 0))) \
        if rng_in_kernel else (u, pl.BlockSpec((block, d), lambda i: (i, 0)))

    def full(*shape):
        return pl.BlockSpec(shape, lambda i, _n=len(shape): (0,) * _n)

    zeros = (jnp.zeros((d * ninc,), accum),
             jnp.zeros((d * ninc,), accum),
             jnp.zeros((n_pad,), accum),
             jnp.zeros((n_pad,), accum))
    n_in = 3 + len(flat_consts)     # positional index of the first zeros arg
    extra = {}
    if num_warps is not None:
        from jax.experimental.pallas import triton as plgpu
        extra["compiler_params"] = plgpu.TritonCompilerParams(
            num_warps=num_warps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            first_in[1],                                    # key bits | u
            pl.BlockSpec((block,), lambda i: (i,)),         # cube ids
            pl.BlockSpec((2, d * ninc), lambda i: (0, 0)),  # flat tables
            *const_specs,                                   # integrand consts
            full(d * ninc), full(d * ninc),                 # zeros: ms, mc
            full(n_pad), full(n_pad),                       # zeros: s1, s2
        ],
        out_specs=[full(d * ninc), full(d * ninc), full(n_pad), full(n_pad)],
        out_shape=[
            jax.ShapeDtypeStruct((d * ninc,), accum),
            jax.ShapeDtypeStruct((d * ninc,), accum),
            jax.ShapeDtypeStruct((n_pad,), accum),
            jax.ShapeDtypeStruct((n_pad,), accum),
        ],
        input_output_aliases={n_in: 0, n_in + 1: 1, n_in + 2: 2, n_in + 3: 3},
        interpret=interpret,
        **extra,
    )(first_in[0], cube, ew, *flat_consts, *zeros)


def fill(edges, n_h, key, integrand, *, nstrat: int, n_cap: int, chunk: int,
         dtype=jnp.float32, accum_dtype=None, interpret: bool | None = None,
         block: int | None = None, num_warps: int | None = None,
         start_chunk=0, n_chunks: int | None = None, kahan: bool = False,
         return_comp: bool = False, rng_in_kernel: bool | None = None):
    """GPU-kernel fill returning ``core.fill.FillResult``, scan-chunked
    exactly like ``ops.fill``: chunk ``g`` draws from ``fold_in(key, g)``
    and ``start_chunk``/``n_chunks`` select a contiguous chunk range (the
    unit ``dist.sharded_fill`` distributes, DESIGN.md C5) — so the sharding,
    batching, and early-stop machinery compose with this backend unchanged.

    ``interpret=None`` autodetects with family='gpu': compiled Triton on a
    GPU platform, the Pallas interpreter elsewhere (CPU CI).
    ``rng_in_kernel=None`` resolves to ``not interpret`` — same XLA:CPU
    threefry escape hatch as the TPU path, bit-identical either way.
    ``accum_dtype``/``return_comp`` follow the shared contract documented on
    ``ops.fill`` (§15 widened accumulation; Kahan compensation pair for the
    shard boundary).
    """
    from repro.core.fill import FillResult
    from .ops import hoist_closure, key_bits

    if return_comp and not kahan:
        raise ValueError("return_comp=True requires kahan=True (there is "
                         "no compensation term to return)")
    interpret = resolve_interpret(interpret, family="gpu")
    if rng_in_kernel is None:
        rng_in_kernel = not interpret
    dtype = jnp.dtype(dtype)
    accum = jnp.dtype(accum_dtype) if accum_dtype is not None else dtype
    if dtype != jnp.float32:
        raise ValueError(
            f"pallas-gpu is f32-only (the in-kernel RNG reproduces the f32 "
            f"uniform bit pattern; widen accum_dtype instead, §15); "
            f"got dtype={dtype}")
    if accum not in (jnp.float32, jnp.float64):
        raise ValueError(f"accum_dtype must be float32 or float64, "
                         f"got {accum}")
    d = edges.shape[0]
    ninc = edges.shape[1] - 1
    n_cubes = n_h.shape[0]
    if n_chunks is None:
        assert n_cap % chunk == 0, (n_cap, chunk)
        n_chunks = n_cap // chunk
    block = _pick_block(block, chunk, d, ninc, accum.itemsize)

    edges_lo = edges[:, :-1].astype(dtype)
    widths = jnp.diff(edges, axis=1).astype(dtype)
    pure_ig, ig_consts = hoist_closure(integrand, (block, d), dtype)

    def chunk_contrib(gchunk):
        k = jax.random.fold_in(key, gchunk)
        cube = strat.cubes_for_slice(n_h, gchunk * chunk, chunk)
        u = (None if rng_in_kernel else
             jax.random.uniform(k, (chunk, d), dtype=dtype))
        ms, mc, s1p, s2p = vegas_fill_gpu(
            key_bits(k).reshape(1, 2), cube, edges_lo, widths,
            nstrat=nstrat, n_cubes=n_cubes, integrand=pure_ig, block=block,
            interpret=interpret, num_warps=num_warps, u=u,
            ig_consts=ig_consts, accum_dtype=accum)
        return FillResult(ms.reshape(d, ninc), mc.reshape(d, ninc),
                          s1p[:n_cubes], s2p[:n_cubes])

    def body(carry, step):
        contrib = chunk_contrib(start_chunk + step)
        if not kahan:
            return carry + contrib, None
        acc, comp = carry
        y = jax.tree.map(jnp.subtract, contrib, comp)
        t = jax.tree.map(jnp.add, acc, y)
        comp = jax.tree.map(lambda tt, a, yy: (tt - a) - yy, t, acc, y)
        return (t, comp), None

    zero = FillResult(jnp.zeros((d, ninc), accum), jnp.zeros((d, ninc), accum),
                      jnp.zeros((n_cubes,), accum), jnp.zeros((n_cubes,), accum))
    init = (zero, zero) if kahan else zero
    out, _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    if kahan:
        return out if return_comp else out[0]
    return out
