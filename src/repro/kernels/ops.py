"""Jitted wrapper exposing the Pallas fill kernels behind the core FillResult
contract (the 'pallas'/'pallas-fused' entries of the engine's backend
registry, via core.fill.fill_pallas).

The fill is scan-chunked exactly like ``core.fill.fill_reference``: chunk
``g`` draws its uniforms from ``fold_in(key, g)`` and its cube ids from the
global eval offset ``g * chunk``, so live memory is bounded by one chunk
(never by ``n_cap``) and ``start_chunk``/``n_chunks`` select a contiguous
chunk range — the unit ``dist.sharded_fill`` distributes (DESIGN.md C5).

Two kernel paths (DESIGN.md §7):
  * ``fused_cubes=False`` (P-V2 baseline): uniforms materialized per chunk in
    HBM, per-eval weights streamed back out, cube reduction via XLA
    segment-sum over the sorted ids.
  * ``fused_cubes=True``  (P-V3): the streaming kernel — in-kernel threefry
    RNG (bit-identical streams) + VMEM-resident cube accumulation; no
    per-eval array ever exists, in HBM or as a kernel output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import strat
from . import resolve_interpret
from . import vegas_fill as vk


def hoist_closure(integrand, x_shape, dtype):
    """Split ``integrand`` into a closure-free function + the arrays it
    closes over (ridge's peak table, a batched family's vmapped params, ...).

    A traced pallas kernel body may not capture constants or outer-trace
    tracers, so ops.fill hoists them here and ships them through the kernel
    as explicit inputs.  (``jax.closure_convert`` is not enough: it hoists
    only tracers involved in differentiation, leaving plain array constants
    in the closure.)  Returns ``(pure_fn(x, *consts), consts)``.
    """
    closed = jax.make_jaxpr(lambda xx: integrand(xx))(
        jax.ShapeDtypeStruct(x_shape, dtype))
    consts = tuple(closed.consts)

    def pure(x, *cs):
        out = jax.core.eval_jaxpr(closed.jaxpr, list(cs), x)
        return out[0]

    return pure, consts


def key_bits(key) -> jax.Array:
    """Raw (2,) uint32 key data for either a legacy raw key or a typed key."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(key)
    return key


def tile_footprint_bytes(tile: int, d: int, ninc: int, n_cubes: int, *,
                         accum_itemsize: int = 4) -> int:
    """VMEM footprint of one kernel tile under the DESIGN.md §7/§15 budget
    math: the d pass-1 one-hots stay live for pass-2 reuse (d * tile * ninc,
    f32 — products feed the MXU in the sample dtype), the cube-window
    one-hot adds tile * span, the transform scratch ~8 copies of (tile, d),
    plus the grid-resident state — the f32 map tables (2 * d * ninc) and the
    ACCUMULATORS at ``accum_itemsize`` bytes apiece: the (d, ninc) ms/mc
    histogram pair and the two (rows, LANE) cube-moment tiles (~2.1 MB f32 /
    ~4.2 MB f64 at the max_cubes = 2^18 cap).  Widened f64 accumulation
    therefore shrinks the budget available to per-tile scratch — the §15
    VMEM tradeoff `valid_tiles` prices."""
    span = vk.span_for_tile(tile)
    resident = (4 * 2 * d * ninc
                + accum_itemsize * (2 * d * ninc
                                    + 2 * vk.padded_cube_rows(n_cubes, tile)
                                    * vk.LANE))
    return 4 * (d * tile * ninc + tile * span + 8 * tile * d) + resident


def valid_tiles(chunk: int, d: int, ninc: int, n_cubes: int, *,
                vmem_budget: int = 8 << 20,
                max_tile: int = 1024, accum_itemsize: int = 4) -> list[int]:
    """Every tile the kernel accepts for this shape, ascending: divisors of
    ``chunk`` whose :func:`tile_footprint_bytes` fits the VMEM budget.

    This is the single validity oracle shared by :func:`autotune_tile` (which
    takes the largest entry) and the plan autotuner (`engine.autotune`, which
    scores entries with the measured cost model) — so the autotuner can never
    choose a tile the kernel would reject.  ``accum_itemsize`` prices the
    grid-resident accumulators (8 under an f64 PrecisionPolicy, §15).
    """
    return [t for t in range(1, min(chunk, max_tile) + 1)
            if chunk % t == 0
            and tile_footprint_bytes(t, d, ninc, n_cubes,
                                     accum_itemsize=accum_itemsize)
            <= vmem_budget]


def autotune_tile(chunk: int, d: int, ninc: int, n_cubes: int, *,
                  vmem_budget: int = 8 << 20, max_tile: int = 1024,
                  accum_itemsize: int = 4) -> int:
    """Largest tile that divides ``chunk`` and fits the VMEM budget (the
    static default when no measured cost table picks one)."""
    tiles = valid_tiles(chunk, d, ninc, n_cubes, vmem_budget=vmem_budget,
                        max_tile=max_tile, accum_itemsize=accum_itemsize)
    return tiles[-1] if tiles else 1


def _pick_tile(tile: int | None, chunk: int, d: int, ninc: int,
               n_cubes: int, accum_itemsize: int = 4) -> int:
    if tile is None:
        tile = autotune_tile(chunk, d, ninc, n_cubes,
                             accum_itemsize=accum_itemsize)
    else:
        tile = min(tile, chunk)
        if chunk % tile != 0:
            # The scanned grid is per-chunk, so the tile must divide chunk:
            # fall back to the largest divisor below the request.
            tile = next(t for t in range(tile, 0, -1) if chunk % t == 0)
    if tile < min(8, chunk):
        # e.g. a prime chunk: the only divisor is 1, which would explode the
        # sequential grid (catastrophic under interpret mode).
        raise ValueError(
            f"chunk={chunk} has no usable tile divisor <= {tile}; "
            f"pick a chunk with a divisor >= 8 (or a tile dividing it)")
    return tile


def fill(edges, n_h, key, integrand, *, nstrat: int, n_cap: int, chunk: int,
         dtype=jnp.float32, accum_dtype=None, interpret: bool | None = None,
         fused_cubes: bool = True, tile: int | None = None, start_chunk=0,
         n_chunks: int | None = None, kahan: bool = False,
         return_comp: bool = False, rng_in_kernel: bool | None = None):
    """Kernel-backed fill pass returning core.fill.FillResult.

    RNG follows the same global-chunk contract as core.fill.fill_reference:
    uniforms for global chunk g are uniform(fold_in(key, g)) — bit-identical
    streams across backends and elastic across any device count.  ``kahan``
    carries a compensation term through the chunk scan (device-count
    invariance, DESIGN.md §5).

    ``rng_in_kernel=None`` resolves to ``not interpret``: the streaming
    kernel generates its own uniforms when compiled for TPU (zero per-eval
    float traffic), while the interpreter gets them precomputed per chunk —
    bit-identical either way, see ``vegas_fill.vegas_fill_fused``.

    ``accum_dtype`` (default: ``dtype``) widens every moment accumulator
    (§15): products stay f32 for the MXU, but the fused kernel's VMEM
    accumulator tiles — and the baseline path's XLA scatter-adds — carry the
    wider dtype, and the returned FillResult comes back in it.
    ``return_comp=True`` (with ``kahan=True``) returns the (sums,
    compensation) pair for the shard-boundary psum — see
    ``core.fill.fill_reference``.
    """
    from repro.core.fill import FillResult

    if return_comp and not kahan:
        raise ValueError("return_comp=True requires kahan=True (there is "
                         "no compensation term to return)")
    interpret = resolve_interpret(interpret)
    if rng_in_kernel is None:
        rng_in_kernel = not interpret
    dtype = jnp.dtype(dtype)
    accum = jnp.dtype(accum_dtype) if accum_dtype is not None else dtype
    d = edges.shape[0]
    ninc = edges.shape[1] - 1
    n_cubes = n_h.shape[0]
    if n_chunks is None:
        assert n_cap % chunk == 0, (n_cap, chunk)
        n_chunks = n_cap // chunk
    tile = _pick_tile(tile, chunk, d, ninc, n_cubes, accum.itemsize)
    if fused_cubes and dtype != jnp.float32:
        raise ValueError(
            f"fused_cubes=True is f32-only samples (the in-kernel RNG "
            f"reproduces the f32 uniform bit pattern; widen accum_dtype "
            f"instead, §15); got dtype={dtype}")
    if accum not in (jnp.float32, jnp.float64):
        raise ValueError(f"accum_dtype must be float32 or float64, "
                         f"got {accum}")

    edges_lo = edges[:, :-1].astype(dtype)
    widths = jnp.diff(edges, axis=1).astype(dtype)
    pure_ig, ig_consts = hoist_closure(integrand, (tile, d), dtype)

    def chunk_contrib(gchunk):
        k = jax.random.fold_in(key, gchunk)
        cube = strat.cubes_for_slice(n_h, gchunk * chunk, chunk)
        if fused_cubes:
            u = (None if rng_in_kernel else
                 jax.random.uniform(k, (chunk, d), dtype=dtype))
            ms, mc, s1p, s2p = vk.vegas_fill_fused(
                key_bits(k).reshape(1, 2), cube.reshape(chunk, 1), edges_lo,
                widths, nstrat=nstrat, n_cubes=n_cubes, integrand=pure_ig,
                tile=tile, interpret=interpret, u=u, ig_consts=ig_consts,
                accum_dtype=accum)
            return FillResult(ms, mc, s1p.reshape(-1)[:n_cubes],
                              s2p.reshape(-1)[:n_cubes])
        u = jax.random.uniform(k, (chunk, d), dtype=dtype)
        w, ms, mc = vk.vegas_fill(u, cube.reshape(chunk, 1), edges_lo, widths,
                                  nstrat=nstrat, n_cubes=n_cubes,
                                  integrand=pure_ig, tile=tile,
                                  interpret=interpret, ig_consts=ig_consts)
        # The baseline kernel streams per-eval weights and per-chunk f32 map
        # partials; the §15 widening happens at the accumulation boundary —
        # the scatter below and the cross-chunk scan run in ``accum``.
        w = w.reshape(chunk).astype(accum)
        # Per-cube reduction outside the kernel (ids are sorted; XLA lowers
        # this to a sorted-scatter; the overflow bucket is dropped).
        s1 = jnp.zeros((n_cubes + 1,), accum).at[cube].add(w)[:n_cubes]
        s2 = jnp.zeros((n_cubes + 1,), accum).at[cube].add(w * w)[:n_cubes]
        return FillResult(ms.astype(accum), mc.astype(accum), s1, s2)

    def body(carry, step):
        contrib = chunk_contrib(start_chunk + step)
        if not kahan:
            return carry + contrib, None
        acc, comp = carry
        y = jax.tree.map(jnp.subtract, contrib, comp)
        t = jax.tree.map(jnp.add, acc, y)
        comp = jax.tree.map(lambda tt, a, yy: (tt - a) - yy, t, acc, y)
        return (t, comp), None

    zero = FillResult(jnp.zeros((d, ninc), accum), jnp.zeros((d, ninc), accum),
                      jnp.zeros((n_cubes,), accum), jnp.zeros((n_cubes,), accum))
    init = (zero, zero) if kahan else zero
    out, _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    if kahan:
        return out if return_comp else out[0]
    return out
