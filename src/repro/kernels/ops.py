"""Jitted wrapper exposing the Pallas fill kernel behind the core FillResult
contract (core/fill.py BACKENDS['pallas'])."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import strat
from . import vegas_fill as vk


def fill(edges, n_h, key, integrand, *, nstrat: int, n_cap: int, chunk: int,
         dtype=jnp.float32, interpret: bool = True, fused_cubes: bool = False,
         tile: int = 256, start_chunk=0, n_chunks: int | None = None):
    """Kernel-backed fill pass returning core.fill.FillResult.

    Baseline decomposition (paper-faithful): the kernel produces per-eval
    weights + the importance-map histogram; the per-cube reduction runs as an
    XLA segment-sum over the (sorted) cube ids. ``fused_cubes`` switches to
    in-kernel cube accumulation (perf iteration P-V3).

    RNG follows the same global-chunk contract as core.fill.fill_reference:
    uniforms for global chunk g are uniform(fold_in(key, g)) — elastic across
    any device count.
    """
    from repro.core.fill import FillResult

    del fused_cubes  # P-V3; baseline path below
    d = edges.shape[0]
    ninc = edges.shape[1] - 1
    n_cubes = n_h.shape[0]
    if n_chunks is None:
        assert n_cap % chunk == 0, (n_cap, chunk)
        n_chunks = n_cap // chunk
    n_local = n_chunks * chunk
    tile = min(tile, n_local)
    if n_local % tile != 0:
        # Non-power-of-two chunk shapes: the Pallas grid needs tile | n_local.
        # chunk always divides n_local (= n_chunks * chunk), so fall back to
        # the largest divisor of chunk that fits the requested tile.
        cap = min(tile, chunk)
        tile = next(t for t in range(cap, 0, -1) if chunk % t == 0)
        if tile < min(8, chunk):
            # e.g. a prime chunk: the only divisor is 1, which would explode
            # the sequential grid (catastrophic under interpret mode).
            raise ValueError(
                f"chunk={chunk} has no usable tile divisor <= {cap}; "
                f"pick a chunk with a divisor >= 8 (or a tile dividing it)")

    gchunks = start_chunk + jnp.arange(n_chunks)
    keys = jax.vmap(lambda g: jax.random.fold_in(key, g))(gchunks)
    u = jax.vmap(lambda k: jax.random.uniform(k, (chunk, d), dtype=dtype))(keys)
    u = u.reshape(n_local, d)
    cube = strat.cubes_for_slice(n_h, start_chunk * chunk, n_local)

    edges_lo = edges[:, :-1].astype(dtype)
    widths = jnp.diff(edges, axis=1).astype(dtype)

    w, ms, mc = vk.vegas_fill(u, cube.reshape(n_local, 1), edges_lo, widths,
                              nstrat=nstrat, n_cubes=n_cubes,
                              integrand=integrand, tile=tile,
                              interpret=interpret)
    w = w.reshape(n_local)
    # Per-cube reduction outside the kernel (cube ids are sorted; XLA lowers
    # this to an efficient sorted-scatter on TPU).
    s1 = jnp.zeros((n_cubes + 1,), dtype).at[cube].add(w)[:n_cubes]
    s2 = jnp.zeros((n_cubes + 1,), dtype).at[cube].add(w * w)[:n_cubes]
    return FillResult(ms, mc, s1, s2)
