"""Pallas TPU kernel for the VEGAS+ fill phase (cuVegas' ``vegasFill``).

One kernel fuses, per VMEM tile of evaluations:
  stratified-sample decode -> map transform + Jacobian -> integrand eval
  -> importance-map weight accumulation.

TPU adaptation of the CUDA design (DESIGN.md D1-D4):
  * cuVegas' per-thread ``atomicAdd`` into the (d, ninc) map histogram becomes
    a one-hot matmul on the MXU: ``onehot(iy_k)^T @ w2`` per dimension.  The
    Pallas grid is sequential on TPU, so ``ms_ref[...] +=`` across tiles is
    race-free by construction — no atomics exist and none are needed.
  * The same one-hot matrix implements the edge/width *gathers* (table
    lookups as (tile, ninc) @ (ninc, 1) matvecs) — random HBM access in the
    CUDA kernel becomes dense VMEM-resident MXU work.
  * The Jacobian is accumulated in log space (overflow-safe for adapted
    high-d maps).
  * The integrand is a traced JAX callable inlined into the kernel body — the
    JAX analogue of cuVegas' Numba-compiled PTX device function.

Block layout per grid step i (grid = n // tile):
  u      (tile, d)   VMEM   uniforms for this tile
  cube   (tile, 1)   VMEM   int32 hypercube ids (n_cubes == masked)
  edges  (d, ninc)   VMEM   left interval edges (replicated across steps)
  widths (d, ninc)   VMEM   interval widths     (replicated across steps)
  w      (tile, 1)   VMEM   per-eval J*f output
  ms/mc  (d, ninc)   VMEM   accumulated across the sequential grid
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TINY = 1e-30


def _fill_kernel(u_ref, cube_ref, edges_ref, widths_ref, w_ref, ms_ref, mc_ref,
                 *, nstrat: int, n_cubes: int, ninc: int, integrand):
    i = pl.program_id(0)
    u = u_ref[...]                      # (tile, d)
    cube = cube_ref[...]                # (tile, 1) int32
    tile, d = u.shape
    dtype = u.dtype

    valid = cube < n_cubes              # (tile, 1)
    cube_c = jnp.minimum(cube, n_cubes - 1)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, ninc), 1)   # (1, ninc)

    # ---- pass 1: per-dimension transform (gathers as one-hot matvecs) ----
    x_cols = []
    iy_cols = []
    logjac = jnp.zeros((tile, 1), dtype)
    for k in range(d):
        c_k = (cube_c // (nstrat**k)) % nstrat                  # (tile, 1)
        y_k = (c_k.astype(dtype) + u[:, k:k + 1]) / nstrat
        yn = y_k * ninc
        iy_k = jnp.clip(yn.astype(jnp.int32), 0, ninc - 1)      # (tile, 1)
        frac = yn - iy_k.astype(dtype)
        oh = (iy_k == lanes).astype(dtype)                      # (tile, ninc)
        e_lo = jax.lax.dot_general(
            oh, edges_ref[k:k + 1, :], (((1,), (1,)), ((), ())),
            preferred_element_type=dtype)                       # (tile, 1)
        dx = jax.lax.dot_general(
            oh, widths_ref[k:k + 1, :], (((1,), (1,)), ((), ())),
            preferred_element_type=dtype)                       # (tile, 1)
        x_cols.append(e_lo + frac * dx)
        iy_cols.append(iy_k)
        logjac = logjac + jnp.log(jnp.maximum(ninc * dx, _TINY))

    x = jnp.concatenate(x_cols, axis=1)                         # (tile, d)
    jac = jnp.exp(logjac)                                       # (tile, 1)

    # ---- integrand evaluation (traced into the kernel) ----
    fx = integrand(x).reshape(tile, 1).astype(dtype)
    w = jnp.where(valid, jac * fx, jnp.zeros((), dtype))        # (tile, 1)
    w_ref[...] = w
    w2 = w * w
    cnt = valid.astype(dtype)

    # ---- pass 2: map-histogram accumulation (MXU one-hot contractions) ----
    @pl.when(i == 0)
    def _init():
        ms_ref[...] = jnp.zeros_like(ms_ref)
        mc_ref[...] = jnp.zeros_like(mc_ref)

    for k in range(d):
        oh = (iy_cols[k] == lanes).astype(dtype)                # (tile, ninc)
        ms_k = jax.lax.dot_general(
            w2, oh, (((0,), (0,)), ((), ())),
            preferred_element_type=dtype)                       # (1, ninc)
        mc_k = jax.lax.dot_general(
            cnt, oh, (((0,), (0,)), ((), ())),
            preferred_element_type=dtype)                       # (1, ninc)
        ms_ref[k:k + 1, :] += ms_k
        mc_ref[k:k + 1, :] += mc_k


def vegas_fill(u, cube, edges_lo, widths, *, nstrat: int, n_cubes: int,
               integrand, tile: int = 256, interpret: bool = True):
    """pallas_call wrapper. Shapes as in kernels/ref.py; ``n % tile == 0``."""
    n, d = u.shape
    ninc = edges_lo.shape[1]
    assert n % tile == 0, (n, tile)
    dtype = u.dtype

    kernel = functools.partial(_fill_kernel, nstrat=nstrat, n_cubes=n_cubes,
                               ninc=ninc, integrand=integrand)
    grid = (n // tile,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),      # u
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),      # cube
            pl.BlockSpec((d, ninc), lambda i: (0, 0)),      # edges_lo
            pl.BlockSpec((d, ninc), lambda i: (0, 0)),      # widths
        ],
        out_specs=[
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),      # w
            pl.BlockSpec((d, ninc), lambda i: (0, 0)),      # map sums
            pl.BlockSpec((d, ninc), lambda i: (0, 0)),      # map counts
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), dtype),
            jax.ShapeDtypeStruct((d, ninc), dtype),
            jax.ShapeDtypeStruct((d, ninc), dtype),
        ],
        interpret=interpret,
    )(u, cube, edges_lo, widths)
