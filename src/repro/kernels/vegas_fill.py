"""Pallas TPU kernels for the VEGAS+ fill phase (cuVegas' ``vegasFill``).

Two kernels, one contract (DESIGN.md §7, perf iterations P-V1 -> P-V3):

``vegas_fill`` (baseline, P-V2): fuses, per VMEM tile of evaluations,
  stratified-sample decode -> map transform + Jacobian -> integrand eval
  -> importance-map weight accumulation,
with uniforms streamed IN from HBM and per-eval weights streamed OUT (the
per-cube reduction runs as an XLA segment-sum outside the kernel).

``vegas_fill_fused`` (P-V3): the fully streaming kernel.  Uniforms are
generated INSIDE the kernel (bit-exact threefry, matching
``jax.random.uniform(fold_in(key, g), (chunk, d))`` — see ``chunk_uniforms``)
and the per-cube first/second moments are accumulated into a VMEM-resident
accumulator across the sequential grid, so the only per-eval HBM traffic left
is the (chunk, 1) int32 sorted cube-id input: kernel output size is
O(d*ninc + n_cubes) regardless of how many evaluations stream through.

TPU adaptation of the CUDA design (DESIGN.md D1-D4):
  * cuVegas' per-thread ``atomicAdd`` into the (d, ninc) map histogram becomes
    a one-hot matmul on the MXU: ``onehot(iy_k)^T @ w2`` per dimension.  The
    Pallas grid is sequential on TPU, so ``ms_ref[...] +=`` across tiles is
    race-free by construction — no atomics exist and none are needed.
  * The same one-hot matrix implements the edge/width *gathers* (table
    lookups as (tile, ninc) @ (ninc, 1) matvecs) — random HBM access in the
    CUDA kernel becomes dense VMEM-resident MXU work.
  * The Jacobian is accumulated in log space (overflow-safe for adapted
    high-d maps).
  * The integrand is a traced JAX callable inlined into the kernel body — the
    JAX analogue of cuVegas' Numba-compiled PTX device function.

Block layout per grid step i (grid = n // tile):
  u      (tile, d)   VMEM   uniforms for this tile
  cube   (tile, 1)   VMEM   int32 hypercube ids (n_cubes == masked)
  edges  (d, ninc)   VMEM   left interval edges (replicated across steps)
  widths (d, ninc)   VMEM   interval widths     (replicated across steps)
  w      (tile, 1)   VMEM   per-eval J*f output
  ms/mc  (d, ninc)   VMEM   accumulated across the sequential grid
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TINY = 1e-30


def _fill_kernel(u_ref, cube_ref, edges_ref, widths_ref, *rest,
                 nstrat: int, n_cubes: int, ninc: int, integrand):
    *const_refs, w_ref, ms_ref, mc_ref = rest
    i = pl.program_id(0)
    u = u_ref[...]                      # (tile, d)
    cube = cube_ref[...]                # (tile, 1) int32
    tile, d = u.shape
    dtype = u.dtype

    valid = cube < n_cubes              # (tile, 1)
    cube_c = jnp.minimum(cube, n_cubes - 1)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, ninc), 1)   # (1, ninc)

    # ---- pass 1: per-dimension transform (gathers as one-hot matvecs) ----
    x_cols = []
    iy_cols = []
    logjac = jnp.zeros((tile, 1), dtype)
    for k in range(d):
        c_k = (cube_c // (nstrat**k)) % nstrat                  # (tile, 1)
        y_k = (c_k.astype(dtype) + u[:, k:k + 1]) / nstrat
        yn = y_k * ninc
        iy_k = jnp.clip(yn.astype(jnp.int32), 0, ninc - 1)      # (tile, 1)
        frac = yn - iy_k.astype(dtype)
        oh = (iy_k == lanes).astype(dtype)                      # (tile, ninc)
        e_lo = jax.lax.dot_general(
            oh, edges_ref[k:k + 1, :], (((1,), (1,)), ((), ())),
            preferred_element_type=dtype)                       # (tile, 1)
        dx = jax.lax.dot_general(
            oh, widths_ref[k:k + 1, :], (((1,), (1,)), ((), ())),
            preferred_element_type=dtype)                       # (tile, 1)
        x_cols.append(e_lo + frac * dx)
        iy_cols.append(iy_k)
        logjac = logjac + jnp.log(jnp.maximum(ninc * dx, _TINY))

    x = jnp.concatenate(x_cols, axis=1)                         # (tile, d)
    jac = jnp.exp(logjac)                                       # (tile, 1)

    # ---- integrand evaluation (traced into the kernel; closure consts
    # arrive as trailing refs, see ``_const_transport``) ----
    fx = integrand(x, *[r[...] for r in const_refs])
    fx = fx.reshape(tile, 1).astype(dtype)
    w = jnp.where(valid, jac * fx, jnp.zeros((), dtype))        # (tile, 1)
    w_ref[...] = w
    w2 = w * w
    cnt = valid.astype(dtype)

    # ---- pass 2: map-histogram accumulation (MXU one-hot contractions) ----
    @pl.when(i == 0)
    def _init():
        ms_ref[...] = jnp.zeros_like(ms_ref)
        mc_ref[...] = jnp.zeros_like(mc_ref)

    for k in range(d):
        oh = (iy_cols[k] == lanes).astype(dtype)                # (tile, ninc)
        ms_k = jax.lax.dot_general(
            w2, oh, (((0,), (0,)), ((), ())),
            preferred_element_type=dtype)                       # (1, ninc)
        mc_k = jax.lax.dot_general(
            cnt, oh, (((0,), (0,)), ((), ())),
            preferred_element_type=dtype)                       # (1, ninc)
        ms_ref[k:k + 1, :] += ms_k
        mc_ref[k:k + 1, :] += mc_k


def _const_transport(integrand, ig_consts):
    """Closure constants ride into the kernel as (1, size) VMEM inputs.

    Returns ``(kernel_integrand, flat_consts, const_specs)``: the flattened
    arrays, their full-block BlockSpecs, and a wrapper restoring the original
    shapes before calling ``integrand(x, *consts)``.  Empty for closure-free
    integrands — the common fast path.
    """
    ig_consts = tuple(ig_consts)
    shapes = [jnp.shape(c) for c in ig_consts]
    flat = [jnp.reshape(c, (1, max(int(jnp.size(c)), 1))) for c in ig_consts]
    specs = [pl.BlockSpec(f.shape, lambda i: (0, 0)) for f in flat]

    def kernel_integrand(x, *flat_refs):
        return integrand(x, *[f.reshape(s)
                              for f, s in zip(flat_refs, shapes)])

    return kernel_integrand, flat, specs


def vegas_fill(u, cube, edges_lo, widths, *, nstrat: int, n_cubes: int,
               integrand, tile: int = 256, interpret: bool = True,
               ig_consts=()):
    """pallas_call wrapper. Shapes as in kernels/ref.py; ``n % tile == 0``.

    ``ig_consts``: arrays closed over by ``integrand`` (from
    ``jax.closure_convert``), passed through as kernel inputs — the integrand
    is then called as ``integrand(x, *ig_consts)``.
    """
    n, d = u.shape
    ninc = edges_lo.shape[1]
    assert n % tile == 0, (n, tile)
    dtype = u.dtype
    kig, flat_consts, const_specs = _const_transport(integrand, ig_consts)

    kernel = functools.partial(_fill_kernel, nstrat=nstrat, n_cubes=n_cubes,
                               ninc=ninc, integrand=kig)
    grid = (n // tile,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),      # u
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),      # cube
            pl.BlockSpec((d, ninc), lambda i: (0, 0)),      # edges_lo
            pl.BlockSpec((d, ninc), lambda i: (0, 0)),      # widths
            *const_specs,                                   # integrand consts
        ],
        out_specs=[
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),      # w
            pl.BlockSpec((d, ninc), lambda i: (0, 0)),      # map sums
            pl.BlockSpec((d, ninc), lambda i: (0, 0)),      # map counts
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), dtype),
            jax.ShapeDtypeStruct((d, ninc), dtype),
            jax.ShapeDtypeStruct((d, ninc), dtype),
        ],
        interpret=interpret,
    )(u, cube, edges_lo, widths, *flat_consts)


# ---------------------------------------------------------------------------
# In-kernel RNG (P-V3 part 1): threefry-2x32 counter mode, bit-exact with
# jax.random.uniform under the default (non-partitionable) threefry impl.
# ---------------------------------------------------------------------------

LANE = 128          # TPU lane width: cube-accumulator rows/offsets align to it
_ROT_A = (13, 15, 26, 6)
_ROT_B = (17, 29, 16, 24)


def _rotl32(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def _threefry2x32(k0, k1, x0, x1):
    """The Threefry-2x32 block cipher on uint32 arrays, written in plain jnp
    ops (shifts/xor/add) so it traces into a Pallas kernel body — same key
    schedule and rotation constants as jax._src.prng.threefry2x32_p, so the
    outputs are bit-identical to what ``jax.random`` produces."""
    k2 = k0 ^ k1 ^ jnp.uint32(0x1BD11BDA)
    x0 = x0 + k0
    x1 = x1 + k1
    sched = ((k1, k2), (k2, k0), (k0, k1), (k1, k2), (k2, k0))
    rots = (_ROT_A, _ROT_B, _ROT_A, _ROT_B, _ROT_A)
    for i in range(5):
        for r in rots[i]:
            x0 = x0 + x1
            x1 = _rotl32(x1, r)
            x1 = x0 ^ x1
        a, b = sched[i]
        x0 = x0 + a
        x1 = x1 + b + jnp.uint32(i + 1)
    return x0, x1


def _partitionable() -> bool:
    """The jax_threefry_partitionable flag, read at TRACE time: it selects
    which of jax's two threefry counter layouts the in-kernel RNG must
    reproduce (flipping the flag between trace and execution is not
    supported — neither is it for jax.random itself under jit)."""
    return bool(jax.config.jax_threefry_partitionable)


def _uniform_from_counts(k0, k1, c, n_total: int):
    """f32 uniforms in [0, 1) for flat counter positions ``c`` (uint32) of a
    ``jax.random.uniform(key, shape)`` draw with ``prod(shape) == n_total``.

    Matches jax's threefry counter layout bit-for-bit under BOTH settings of
    ``jax_threefry_partitionable``:
      * partitionable: element ``c`` is ``xor(threefry(key, hi32(c),
        lo32(c)))`` — purely per-element (requires ``n_total < 2**32``, which
        a chunk always satisfies);
      * original: ``iota(n_total)`` is split into two halves (the odd case
        pads one zero) fed as the two cipher inputs, so element ``c`` lives
        in block ``c mod half`` and takes cipher output 0 or 1 by half.
    The float conversion mirrors ``jax._src.random._uniform``: randomize the
    mantissa at exponent 0 and subtract 1.
    """
    if _partitionable():
        o0, o1 = _threefry2x32(k0, k1, jnp.zeros_like(c), c)
        bits = o0 ^ o1
    else:
        half = (n_total + 1) // 2
        in_lo = c < jnp.uint32(half)
        b = jnp.where(in_lo, c, c - jnp.uint32(half))
        hi = b + jnp.uint32(half)
        if n_total % 2:
            hi = jnp.where(hi == jnp.uint32(n_total), jnp.uint32(0), hi)
        o0, o1 = _threefry2x32(k0, k1, b, hi)
        bits = jnp.where(in_lo, o0, o1)
    fb = (bits >> jnp.uint32(9)) | jnp.uint32(0x3F800000)
    u = jax.lax.bitcast_convert_type(fb, jnp.float32) - jnp.float32(1.0)
    return jnp.maximum(u, jnp.float32(0.0))


def _tile_uniforms(k0, k1, row0, tile: int, chunk: int, d: int):
    """(tile, d) uniforms == rows [row0, row0+tile) of
    ``jax.random.uniform(key, (chunk, d))`` for the key behind (k0, k1)."""
    rows = jax.lax.broadcasted_iota(jnp.uint32, (tile, d), 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (tile, d), 1)
    c = (jnp.uint32(row0) + rows) * jnp.uint32(d) + cols
    return _uniform_from_counts(k0, k1, c, chunk * d)


def chunk_uniforms(key_bits, *, chunk: int, d: int, tile: int | None = None):
    """Reassemble a whole chunk's uniforms from per-tile in-kernel draws.

    ``key_bits``: (2,) uint32 raw key data of ``fold_in(key, g)``.  Equals
    ``jax.random.uniform(fold_in(key, g), (chunk, d))`` BIT-FOR-BIT (the RNG
    contract test); ``tile`` exercises the same slicing the kernel grid uses.
    """
    tile = chunk if tile is None else tile
    assert chunk % tile == 0, (chunk, tile)
    k0, k1 = key_bits[0], key_bits[1]
    parts = [_tile_uniforms(k0, k1, i * tile, tile, chunk, d)
             for i in range(chunk // tile)]
    return jnp.concatenate(parts, axis=0)


def span_for_tile(tile: int) -> int:
    """Width of the per-tile cube-id window: sorted ids advance by at most one
    per eval, so a tile touches <= tile distinct ids; aligning the window base
    down to a LANE boundary costs at most LANE - 1 extra slots."""
    return ((tile + LANE - 1) // LANE) * LANE + LANE


def padded_cube_rows(n_cubes: int, tile: int) -> int:
    """Rows of the (rows, LANE) VMEM cube accumulator: the highest window base
    is align_down(n_cubes - 1), and the window extends span slots past it."""
    return (max(n_cubes - 1, 0) // LANE) + span_for_tile(tile) // LANE


# ---------------------------------------------------------------------------
# P-V3 fused kernel: in-kernel RNG + in-kernel cube accumulation
# ---------------------------------------------------------------------------

def _fill_fused_kernel(*refs, nstrat: int, n_cubes: int, ninc: int,
                       chunk: int, tile: int, d: int, integrand,
                       rng_in_kernel: bool, accum_dtype=jnp.float32):
    (rng_or_u_ref, cube_ref, ew_ref, *const_refs,
     ms_ref, mc_ref, s1_ref, s2_ref) = refs
    if rng_in_kernel:
        kd_ref = rng_or_u_ref
    else:
        u_ref = rng_or_u_ref
    i = pl.program_id(0)
    dtype = jnp.float32
    cube = cube_ref[...]                        # (tile, 1) int32, sorted

    if rng_in_kernel:
        # ---- in-kernel RNG: this tile's slice of uniform(fold_in(key, g)),
        # bit-exact (P-V3 part 1; zero per-eval input traffic) ----
        u = _tile_uniforms(kd_ref[0, 0], kd_ref[0, 1], i * tile, tile,
                           chunk, d)                            # (tile, d)
    else:
        u = u_ref[...]                                          # (tile, d)

    valid = cube < n_cubes                      # (tile, 1)
    cube_c = jnp.minimum(cube, n_cubes - 1)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, ninc), 1)   # (1, ninc)

    # ---- pass 1: per-dimension transform.  One STACKED gather matvec per
    # dimension: oh @ [edges_k; widths_k]^T picks (e_lo, dx) together — half
    # the baseline's MXU ops / VMEM passes for the table lookups. ----
    x_cols = []
    ohs = []                                    # kept live for pass 2 reuse
    logjac = jnp.zeros((tile, 1), dtype)
    for k in range(d):
        c_k = (cube_c // (nstrat**k)) % nstrat                  # (tile, 1)
        y_k = (c_k.astype(dtype) + u[:, k:k + 1]) / nstrat
        yn = y_k * ninc
        iy_k = jnp.clip(yn.astype(jnp.int32), 0, ninc - 1)      # (tile, 1)
        frac = yn - iy_k.astype(dtype)
        oh = (iy_k == lanes).astype(dtype)                      # (tile, ninc)
        ed = jax.lax.dot_general(
            oh, ew_ref[2 * k:2 * k + 2, :], (((1,), (1,)), ((), ())),
            preferred_element_type=dtype)                       # (tile, 2)
        e_lo = ed[:, 0:1]
        dx = ed[:, 1:2]
        x_cols.append(e_lo + frac * dx)
        ohs.append(oh)
        logjac = logjac + jnp.log(jnp.maximum(ninc * dx, _TINY))

    x = jnp.concatenate(x_cols, axis=1)                         # (tile, d)
    jac = jnp.exp(logjac)                                       # (tile, 1)

    # ---- integrand evaluation (traced into the kernel; closure consts
    # arrive as trailing refs, see ``_const_transport``) ----
    fx = integrand(x, *[r[...] for r in const_refs])
    fx = fx.reshape(tile, 1).astype(dtype)
    w = jnp.where(valid, jac * fx, jnp.zeros((), dtype))        # (tile, 1)
    w2 = w * w
    cnt = valid.astype(dtype)

    @pl.when(i == 0)
    def _init():
        ms_ref[...] = jnp.zeros_like(ms_ref)
        mc_ref[...] = jnp.zeros_like(mc_ref)
        s1_ref[...] = jnp.zeros_like(s1_ref)
        s2_ref[...] = jnp.zeros_like(s2_ref)

    # ---- pass 2: map histogram.  REUSES the pass-1 one-hots (no second
    # construction) and contracts [w2, cnt] in ONE stacked matmul per dim
    # (the baseline runs two).  Products run in f32 on the MXU; the §15
    # widening happens on the per-tile partial, just before the running
    # sum into the (possibly f64) VMEM accumulator ref. ----
    accum = jnp.dtype(accum_dtype)
    w2cnt = jnp.concatenate([w2, cnt], axis=1)                  # (tile, 2)
    for k in range(d):
        m_k = jax.lax.dot_general(
            w2cnt, ohs[k], (((0,), (0,)), ((), ())),
            preferred_element_type=dtype)                       # (2, ninc)
        m_k = m_k.astype(accum)
        ms_ref[k:k + 1, :] += m_k[0:1, :]
        mc_ref[k:k + 1, :] += m_k[1:2, :]

    # ---- fused cube accumulation (P-V3 part 2) ----
    # Sorted ids advance by <= 1 per eval (every cube draws >= 2 evals), so
    # this tile's live ids fit a contiguous window of `span` slots starting at
    # a LANE-aligned base below the first id.  One-hot against the WINDOW
    # (tile x span, tiny) instead of all n_cubes; masked overflow evals are
    # clipped into the window but contribute exactly 0.
    span = span_for_tile(tile)
    base = (cube_c[0, 0] // LANE) * LANE                        # scalar
    rel = jnp.clip(cube_c - base, 0, span - 1)                  # (tile, 1)
    win = jax.lax.broadcasted_iota(jnp.int32, (1, span), 1)
    ohc = (rel == win).astype(dtype)                            # (tile, span)
    both = jnp.concatenate([w, w2], axis=1)                     # (tile, 2)
    parts = jax.lax.dot_general(
        both, ohc, (((0,), (0,)), ((), ())),
        preferred_element_type=dtype)                           # (2, span)
    rows_n = span // LANE
    br = base // LANE
    # Same §15 boundary as the map histogram: the one-hot contraction stays
    # f32, each tile's (rows_n, LANE) partial is widened once before the
    # grid-sequential += into the accumulator tiles.
    p1 = parts[0:1, :].reshape(rows_n, LANE).astype(accum)
    p2 = parts[1:2, :].reshape(rows_n, LANE).astype(accum)
    s1_ref[pl.ds(br, rows_n), :] += p1
    s2_ref[pl.ds(br, rows_n), :] += p2


def vegas_fill_fused(key_bits, cube, edges_lo, widths, *, nstrat: int,
                     n_cubes: int, integrand, tile: int = 256,
                     interpret: bool = True, u=None, ig_consts=(),
                     accum_dtype=None):
    """pallas_call wrapper for the P-V3 streaming kernel (one chunk).

    Args:
      key_bits: (1, 2) uint32 raw key data of ``fold_in(key, gchunk)``.
      cube:     (chunk, 1) int32 SORTED cube ids; ``n_cubes`` == masked.
      edges_lo/widths: (d, ninc) f32 map tables.
      accum_dtype: accumulator dtype (default f32).  Under the §15 widened
                policy the four output buffers — and the VMEM accumulator
                tiles behind them — are f64 while every product (transform,
                integrand, one-hot matmuls) stays f32 for the MXU; each
                tile's partial is widened once before the running ``+=``.
      u:        optional (chunk, d) f32 uniforms.  ``None`` (the compiled-TPU
                default) generates them IN-KERNEL from ``key_bits`` — zero
                per-eval input traffic.  Passing the precomputed chunk block
                keeps the rest of the fusion but streams uniforms from HBM:
                the interpret-mode escape hatch (XLA:CPU refuses to vectorize
                fusion clusters polluted by the in-body threefry, a ~2x
                pessimization measured in DESIGN.md §7 — irrelevant on real
                TPU where Mosaic compiles the u32 rotate/xor chain natively).

    Returns ``(ms, mc, s1_pad, s2_pad)`` where the cube moments come back as
    (rows, LANE) f32 — flatten and trim to ``n_cubes``.  No per-eval output
    exists: with in-kernel RNG the only per-eval HBM traffic is the int32
    cube-id input; kernel output is O(d*ninc + n_cubes) state.
    """
    chunk = cube.shape[0]
    d, ninc = edges_lo.shape
    assert chunk % tile == 0, (chunk, tile)
    assert edges_lo.dtype == jnp.float32, "fused path is f32-only (RNG contract)"
    accum = jnp.dtype(accum_dtype) if accum_dtype is not None else jnp.float32
    rows = padded_cube_rows(n_cubes, tile)
    rng_in_kernel = u is None
    # Interleave the two map tables (rows 2k / 2k+1 = edges_k / widths_k) so
    # pass 1 picks both with a single stacked gather matvec per dimension.
    ew = jnp.stack([edges_lo, widths], axis=1).reshape(2 * d, ninc)
    kig, flat_consts, const_specs = _const_transport(integrand, ig_consts)

    kernel = functools.partial(
        _fill_fused_kernel, nstrat=nstrat, n_cubes=n_cubes, ninc=ninc,
        chunk=chunk, tile=tile, d=d, integrand=kig,
        rng_in_kernel=rng_in_kernel, accum_dtype=accum)
    grid = (chunk // tile,)
    first_in = (key_bits, pl.BlockSpec((1, 2), lambda i: (0, 0))) \
        if rng_in_kernel else (u, pl.BlockSpec((tile, d), lambda i: (i, 0)))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            first_in[1],                                    # key bits | u
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),      # cube ids
            pl.BlockSpec((2 * d, ninc), lambda i: (0, 0)),  # edges/widths
            *const_specs,                                   # integrand consts
        ],
        out_specs=[
            pl.BlockSpec((d, ninc), lambda i: (0, 0)),      # map sums
            pl.BlockSpec((d, ninc), lambda i: (0, 0)),      # map counts
            pl.BlockSpec((rows, LANE), lambda i: (0, 0)),   # cube s1
            pl.BlockSpec((rows, LANE), lambda i: (0, 0)),   # cube s2
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, ninc), accum),
            jax.ShapeDtypeStruct((d, ninc), accum),
            jax.ShapeDtypeStruct((rows, LANE), accum),
            jax.ShapeDtypeStruct((rows, LANE), accum),
        ],
        interpret=interpret,
    )(first_in[0], cube, ew, *flat_consts)
