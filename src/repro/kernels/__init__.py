"""Pallas kernel layer: the fill hot-spot the paper optimizes with a custom
CUDA kernel (vegas_fill.py + gpu_fill.py + ops.py + ref.py) plus the
platform policy shared by every caller.

Two policies live here:

  * :func:`backend_default` — the platform-default REGISTRY BACKEND:
    ``'pallas-fused'`` on TPU (the Mosaic-lowered P-V3 kernel),
    ``'pallas-gpu'`` on GPU (the Triton-lowered scatter kernel),
    ``'ref'`` everywhere else.  Logs the detected ``device_kind`` once —
    the same key the cost tables are qualified by (`engine.autotune`).
  * :func:`resolve_interpret` — the per-kernel-family execution mode.
    ``interpret=None`` (the default everywhere) autodetects: compiled on
    the family's native platform (Mosaic on TPU for ``family='tpu'``,
    Triton on GPU for ``family='gpu'``), the Pallas interpreter elsewhere.
    Explicit True/False is honored but logged loudly — the historical
    failure mode was ``interpret=True`` silently running the
    (orders-of-magnitude slower) interpreter on real accelerators.
"""

from __future__ import annotations

import functools
import logging

import jax

log = logging.getLogger("repro.kernels")

#: Which registry backend each platform compiles natively.
PLATFORM_BACKENDS = {"tpu": "pallas-fused", "gpu": "pallas-gpu"}

_FAMILY_COMPILER = {"tpu": "Mosaic", "gpu": "Triton"}


def device_kind() -> str:
    """The detected accelerator model (``'cpu'`` / ``'TPU v4'`` /
    ``'NVIDIA H100 ...'``) — the key BENCH rows and cost-table classes are
    qualified by, so numbers from different silicon never mix."""
    try:
        return jax.devices()[0].device_kind
    except Exception:
        return "unknown"


@functools.lru_cache(maxsize=None)
def _announce_default(platform: str, kind: str, name: str) -> None:
    log.info("Platform-default fill backend: %s (platform=%s, "
             "device_kind=%s)", name, platform, kind)


def backend_default() -> str:
    """The registry backend this platform compiles natively:
    ``'pallas-fused'`` on TPU, ``'pallas-gpu'`` on GPU, ``'ref'`` elsewhere
    (CPU CI — where the pallas backends still run, interpreted, when asked
    for explicitly).  Logs the detected ``device_kind`` once per process;
    ``ExecutionConfig(backend='auto')`` resolves through this."""
    platform = jax.default_backend()
    name = PLATFORM_BACKENDS.get(platform, "ref")
    _announce_default(platform, device_kind(), name)
    return name


@functools.lru_cache(maxsize=None)
def _announce(platform: str, family: str, mode: str, source: str) -> None:
    native = _FAMILY_COMPILER.get(family, "native")
    msg = (f"Pallas fill mode: {mode.upper()} on platform={platform} "
           f"[{family} kernel] ({source})")
    if mode == "interpret" and platform == family:
        log.warning("%s — the interpreter is orders of magnitude slower "
                    "than compiled %s; pass interpret=None to autodetect",
                    msg, native)
    elif mode == "compiled" and platform != family:
        log.warning("%s — compiled %s lowering is only supported on "
                    "%s; this will likely fail to lower", msg, native,
                    family.upper())
    else:
        log.info("%s", msg)


def resolve_interpret(interpret: bool | None, family: str = "tpu") -> bool:
    """Resolve the tri-state ``interpret`` flag to a concrete bool, logging
    the choice once per (platform, family, flag) combination.  ``family``
    names the platform whose compiler lowers this kernel natively
    (``'tpu'`` for the Mosaic kernels, ``'gpu'`` for the Triton one)."""
    platform = jax.default_backend()
    if interpret is None:
        chosen = platform != family
        _announce(platform, family, "interpret" if chosen else "compiled",
                  "autodetected, interpret=None")
    else:
        chosen = bool(interpret)
        _announce(platform, family, "interpret" if chosen else "compiled",
                  f"explicit interpret={chosen}")
    return chosen
