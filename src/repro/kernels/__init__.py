"""Pallas kernel layer: the fill hot-spot the paper optimizes with a custom
CUDA kernel (vegas_fill.py + ops.py + ref.py) plus the interpret/compiled
mode policy shared by every caller.

``interpret=None`` (the default everywhere) autodetects: compiled Mosaic on a
real TPU, the Pallas interpreter elsewhere.  Explicit True/False is honored
but logged loudly — the historical failure mode was ``interpret=True``
silently running the (orders-of-magnitude slower) interpreter on real
accelerators.
"""

from __future__ import annotations

import functools
import logging

import jax

log = logging.getLogger("repro.kernels")


def backend_default() -> str:
    """Autodetected Pallas execution mode for this process: ``'compiled'``
    on a real TPU, ``'interpret'`` everywhere else (CPU CI, GPU — the kernel
    is written against the TPU/Mosaic lowering)."""
    return "compiled" if jax.default_backend() == "tpu" else "interpret"


@functools.lru_cache(maxsize=None)
def _announce(platform: str, mode: str, source: str) -> None:
    msg = (f"Pallas fill mode: {mode.upper()} on platform={platform} "
           f"({source})")
    if mode == "interpret" and platform == "tpu":
        log.warning("%s — the interpreter is orders of magnitude slower than "
                    "compiled Mosaic; pass interpret=None to autodetect", msg)
    elif mode == "compiled" and platform != "tpu":
        log.warning("%s — compiled Pallas is only supported on TPU; this "
                    "will likely fail to lower", msg)
    else:
        log.info("%s", msg)


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the tri-state ``interpret`` flag to a concrete bool, logging
    the choice once per (platform, flag) combination."""
    platform = jax.default_backend()
    if interpret is None:
        chosen = backend_default() == "interpret"
        _announce(platform, "interpret" if chosen else "compiled",
                  "autodetected, interpret=None")
    else:
        chosen = bool(interpret)
        _announce(platform, "interpret" if chosen else "compiled",
                  f"explicit interpret={chosen}")
    return chosen
