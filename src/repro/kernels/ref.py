"""Pure-jnp oracle for the vegas_fill Pallas kernel.

Mirrors the kernel contract EXACTLY (same inputs, same outputs, same masking
semantics); tests assert_allclose kernel-vs-ref across shape/dtype sweeps.
"""

from __future__ import annotations

import jax.numpy as jnp


def vegas_fill_ref(u, cube, edges_lo, widths, *, nstrat: int, n_cubes: int,
                   integrand):
    """Oracle for one fill pass.

    Args:
      u:        (n, d) uniforms in [0, 1).
      cube:     (n, 1) int32 hypercube ids; id == n_cubes marks a masked eval.
      edges_lo: (d, ninc) left edge of each map interval.
      widths:   (d, ninc) width of each map interval (> 0).
      nstrat:   stratifications per dimension.
      n_cubes:  number of hypercubes (= nstrat**d).
      integrand: batched f(x (n, d)) -> (n,).

    Returns:
      w          (n, 1)  J*f per eval (0 for masked evals),
      map_sums   (d, ninc) sum of w^2 per (dim, interval),
      map_counts (d, ninc) number of live evals per (dim, interval).
    """
    n, d = u.shape
    ninc = edges_lo.shape[1]
    dtype = u.dtype
    cube = cube.reshape(n)
    valid = cube < n_cubes
    cube_c = jnp.minimum(cube, n_cubes - 1)

    pows = (nstrat ** jnp.arange(d)).astype(jnp.int32)
    coords = ((cube_c[:, None] // pows[None, :]) % nstrat).astype(dtype)
    y = (coords + u) / nstrat
    yn = y * ninc
    iy = jnp.clip(yn.astype(jnp.int32), 0, ninc - 1)
    frac = yn - iy

    e_lo = jnp.take_along_axis(edges_lo.T, iy, axis=0, mode="clip")
    dx = jnp.take_along_axis(widths.T, iy, axis=0, mode="clip")
    x = e_lo + frac * dx
    logjac = jnp.sum(jnp.log(jnp.maximum(ninc * dx, 1e-30)), axis=-1)
    jac = jnp.exp(logjac)

    fx = integrand(x)
    w = jnp.where(valid, jac * fx, jnp.zeros((), dtype))
    w2 = w * w
    cnt = valid.astype(dtype)

    # Map histogram: the contraction onehot(iy)^T @ {w2, cnt} per dimension.
    flat = (jnp.arange(d, dtype=jnp.int32)[None, :] * ninc + iy).reshape(-1)
    ms = jnp.zeros((d * ninc,), dtype).at[flat].add(
        jnp.broadcast_to(w2[:, None], (n, d)).reshape(-1)).reshape(d, ninc)
    mc = jnp.zeros((d * ninc,), dtype).at[flat].add(
        jnp.broadcast_to(cnt[:, None], (n, d)).reshape(-1)).reshape(d, ninc)
    return w.reshape(n, 1), ms, mc


def vegas_fill_fused_ref(u, cube, edges_lo, widths, *, nstrat: int,
                         n_cubes: int, integrand):
    """Oracle for the P-V3 fused kernel: same transform/eval/map histogram as
    :func:`vegas_fill_ref` plus the per-cube moment reduction done in-kernel
    by ``vegas_fill_fused`` (scatter-add over the sorted ids here).

    Takes explicit uniforms (the fused kernel generates them in-kernel; feed
    it ``vegas_fill.chunk_uniforms`` output for bit-identical streams).
    Returns ``(ms, mc, s1 (n_cubes,), s2 (n_cubes,))`` — no per-eval output.
    """
    n = u.shape[0]
    dtype = u.dtype
    w, ms, mc = vegas_fill_ref(u, cube, edges_lo, widths, nstrat=nstrat,
                               n_cubes=n_cubes, integrand=integrand)
    w = w.reshape(n)
    cid = cube.reshape(n)
    s1 = jnp.zeros((n_cubes + 1,), dtype).at[cid].add(w)[:n_cubes]
    s2 = jnp.zeros((n_cubes + 1,), dtype).at[cid].add(w * w)[:n_cubes]
    return ms, mc, s1, s2
