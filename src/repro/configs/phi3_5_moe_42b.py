"""phi3.5-moe-42b-a6.6b [moe]: 32L d=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""

from repro.models.config import ArchConfig, Block, MoeConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=0,
    vocab=32064,
    blocks=(Block("attn", "moe"),),
    moe=MoeConfig(n_experts=16, top_k=2, d_ff=6400),
    rope_theta=10_000.0,
    optimizer="adamw",
    fsdp=True,
    microbatches_train_4k=4,
    sub_quadratic=False,
    remat_group=1,
    moe_ep_over_data=False,
)


def reduced():
    return ArchConfig(
        name="phi3.5-moe-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=0, vocab=256,
        blocks=CONFIG.blocks,
        moe=MoeConfig(n_experts=4, top_k=2, d_ff=96),
        params_dtype="float32", compute_dtype="float32")
