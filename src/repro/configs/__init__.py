"""Config registry: one module per assigned architecture (+ vegas configs).

``get(arch_id)`` returns the full-size ArchConfig; ``get_reduced(arch_id)``
returns the same-family reduced config used by CPU smoke tests.
"""

from __future__ import annotations

import importlib

ARCHS = [
    "llama_3_2_vision_11b",
    "yi_6b",
    "mistral_large_123b",
    "h2o_danube3_4b",
    "smollm_135m",
    "mamba2_1_3b",
    "jamba_1_5_large_398b",
    "musicgen_large",
    "phi3_5_moe_42b",
    "kimi_k2_1t",
]

# canonical ids as given in the assignment -> module names
ALIASES = {
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "yi-6b": "yi_6b",
    "mistral-large-123b": "mistral_large_123b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "smollm-135m": "smollm_135m",
    "mamba2-1.3b": "mamba2_1_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "musicgen-large": "musicgen_large",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
}


def _module(arch_id: str):
    name = ALIASES.get(arch_id, arch_id.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{name}")


def get(arch_id: str):
    return _module(arch_id).CONFIG


def get_reduced(arch_id: str):
    return _module(arch_id).reduced()
