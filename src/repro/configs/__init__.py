"""Config registry: the paper's VEGAS parameter configurations (vegas.py).

The seed repo's LLM architecture configs (and the models/train/serve stack
they parameterized) were removed in PR 4 — they shared nothing with the
integration engine and no tier-1 test or engine code imported them
(DESIGN.md §8 deviations)."""
