"""smollm-135m [dense]: 30L d=576 9H (GQA kv=3) d_ff=1536 vocab=49152 —
llama-arch small, tied embeddings. [hf:HuggingFaceTB/SmolLM-135M; hf]

Too small for tensor parallelism (9 heads don't split 16 ways): sharding
policy is pure data parallelism with replicated params.
"""

from repro.models.config import ArchConfig, Block

CONFIG = ArchConfig(
    name="smollm-135m",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    blocks=(Block("attn", "mlp"),),
    tie_embeddings=True,
    rope_theta=10_000.0,
    optimizer="adamw",
    fsdp=False,
    microbatches_train_4k=4,
    sub_quadratic=False,
    remat_group=1,
)


def reduced():
    return ArchConfig(
        name="smollm-135m-smoke",
        n_layers=3, d_model=48, n_heads=3, n_kv_heads=1, d_ff=128, vocab=256,
        blocks=CONFIG.blocks, tie_embeddings=True,
        params_dtype="float32", compute_dtype="float32")
