"""llama-3.2-vision-11b [vlm]: 40L d=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers every 5th layer (i % 5 == 3).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Modality frontend is a STUB: input_specs() feeds precomputed patch
embeddings (b, 576, d_model) as the cross-attention memory.
"""

from repro.models.config import ArchConfig, Block

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    blocks=(Block("attn", "mlp"), Block("attn", "mlp"), Block("attn", "mlp"),
            Block("xattn", "mlp"), Block("attn", "mlp")),
    xattn_memory_len=576,
    rope_theta=500_000.0,
    optimizer="adamw",
    fsdp=True,
    microbatches_train_4k=4,
    sub_quadratic=False,
    remat_group=1,
)


def reduced():
    return ArchConfig(
        name="llama-3.2-vision-11b-smoke",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab=256,
        blocks=CONFIG.blocks, xattn_memory_len=12,
        params_dtype="float32", compute_dtype="float32")
