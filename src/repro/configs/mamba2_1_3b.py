"""mamba2-1.3b [ssm]: 48L d=2048 (attn-free) vocab=50280, ssm_state=128 —
SSD (state-space duality). [arXiv:2405.21060; unverified]"""

from repro.models.config import ArchConfig, Block, SsmConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    blocks=(Block("mamba", "none"),),
    ssm=SsmConfig(d_state=128, expand=2, head_dim=64, conv_width=4, chunk=256),
    tie_embeddings=True,
    optimizer="adamw",
    fsdp=False,
    microbatches_train_4k=2,
    sub_quadratic=True,        # O(1) decode state
    remat_group=8,
)


def reduced():
    return ArchConfig(
        name="mamba2-1.3b-smoke",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0, vocab=256,
        blocks=CONFIG.blocks,
        ssm=SsmConfig(d_state=16, expand=2, head_dim=16, conv_width=4, chunk=8),
        tie_embeddings=True,
        params_dtype="float32", compute_dtype="float32")
