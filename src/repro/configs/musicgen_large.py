"""musicgen-large [audio]: 48L d=2048 32H (GQA kv=32 = MHA) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only per the assignment: the EnCodec frontend is a stub — the model
consumes audio-codebook token ids directly (input_specs provides them).
"""

from repro.models.config import ArchConfig, Block

CONFIG = ArchConfig(
    name="musicgen-large",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    blocks=(Block("attn", "mlp"),),
    rope_theta=10_000.0,
    optimizer="adamw",
    fsdp=False,
    microbatches_train_4k=4,
    sub_quadratic=False,
    remat_group=8,
)


def reduced():
    return ArchConfig(
        name="musicgen-large-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab=128,
        blocks=CONFIG.blocks,
        params_dtype="float32", compute_dtype="float32")
