"""jamba-1.5-large-398b [hybrid]: 72L d=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE every other
layer. [arXiv:2403.19887; hf]

Period-8 block template: attention at slot 4, Mamba elsewhere; MoE on odd
slots.  (Deviation noted in DESIGN.md: the mixer is our Mamba2/SSD block
rather than Jamba's Mamba1; d_state=128 per our SsmConfig.)
Hybrid => sub-quadratic long-context: the 9 attention layers use
sequence-sharded KV for long_500k decode.
"""

from repro.models.config import ArchConfig, Block, MoeConfig, SsmConfig


def _blocks():
    out = []
    for j in range(8):
        mixer = "attn" if j == 4 else "mamba"
        ffn = "moe" if j % 2 == 1 else "mlp"
        out.append(Block(mixer, ffn))
    return tuple(out)


CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    blocks=_blocks(),
    moe=MoeConfig(n_experts=16, top_k=2, d_ff=24576),
    ssm=SsmConfig(d_state=128, expand=2, head_dim=64, conv_width=4, chunk=256),
    optimizer="adafactor",     # 398B: Adam m/v would not fit a single pod
    params_dtype="bfloat16",   # f32 residuals/cotangents overflow 16GB HBM
    fsdp=True,
    microbatches_train_4k=16,
    sub_quadratic=True,
    remat_group=3,
)


def reduced():
    return ArchConfig(
        name="jamba-1.5-large-398b-smoke",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab=256,
        blocks=_blocks(),
        moe=MoeConfig(n_experts=4, top_k=2, d_ff=96),
        ssm=SsmConfig(d_state=16, expand=2, head_dim=16, conv_width=4, chunk=8),
        params_dtype="float32", compute_dtype="float32")
