"""kimi-k2-1t-a32b [moe]: 61L d=7168 64H (GQA kv=8) vocab=163840,
MoE 384 experts top-8, per-expert d_ff=2048 — trillion-param MoE
(paper-table). [arXiv:2501.kimi2; unverified]

At ~1.04T params: bf16 params + Adafactor (factored second moment) are
required to fit 256 x 16GB chips on the single-pod mesh (DESIGN.md §5);
f32 + Adam would need 12+ TB.
"""

from repro.models.config import ArchConfig, Block, MoeConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=0,
    vocab=163840,
    blocks=(Block("attn", "moe"),),
    moe=MoeConfig(n_experts=384, top_k=8, d_ff=2048),
    head_dim=112,
    rope_theta=50_000.0,
    optimizer="adafactor",
    params_dtype="bfloat16",
    fsdp=True,
    microbatches_train_4k=8,
    sub_quadratic=False,
)


def reduced():
    return ArchConfig(
        name="kimi-k2-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=0, vocab=512,
        blocks=CONFIG.blocks, head_dim=16,
        moe=MoeConfig(n_experts=8, top_k=2, d_ff=32),
        params_dtype="float32", compute_dtype="float32")
