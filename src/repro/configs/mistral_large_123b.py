"""mistral-large-123b [dense]: 88L d=12288 96H (GQA kv=8) d_ff=28672
vocab=32768. [hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""

from repro.models.config import ArchConfig, Block

CONFIG = ArchConfig(
    name="mistral-large-123b",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    blocks=(Block("attn", "mlp"),),
    rope_theta=1_000_000.0,
    optimizer="adamw",
    fsdp=True,                 # 123B f32 + Adam does not fit TP-replicated
    microbatches_train_4k=8,
    sub_quadratic=False,
    remat_group=8,
)


def reduced():
    return ArchConfig(
        name="mistral-large-123b-smoke",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=224, vocab=256,
        blocks=CONFIG.blocks,
        params_dtype="float32", compute_dtype="float32")
