"""The paper's three parameter configurations (Table 2)."""

from repro.core.integrator import VegasConfig

PAPER_CONFIGS = {
    # Configuration 1 (def): cuVegas/Vegas defaults
    "def": VegasConfig(max_it=20, skip=0, ninc=1024, alpha=0.5, beta=0.75),
    # Configuration 2 (vf): matches VegasFlow's hard-coded choices
    "vf": VegasConfig(max_it=20, skip=0, ninc=50, alpha=1.5, beta=0.75),
    # Configuration 3 (tq): matches TorchQuad (n_intervals computed on n_eval)
    "tq": VegasConfig(max_it=20, skip=0, ninc=1024, alpha=0.5, beta=0.75),
}


def tq_ninc(neval: int) -> int:
    """TorchQuad computes the interval count from n_eval."""
    return max(2, min(1024, int((neval / 40) ** 0.5)))
