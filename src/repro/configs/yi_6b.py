"""yi-6b [dense]: 32L d=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 —
llama-arch GQA. [arXiv:2403.04652; hf]"""

from repro.models.config import ArchConfig, Block

CONFIG = ArchConfig(
    name="yi-6b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    blocks=(Block("attn", "mlp"),),
    rope_theta=5_000_000.0,
    optimizer="adamw",
    fsdp=False,
    microbatches_train_4k=2,
    sub_quadratic=False,
    remat_group=8,
)


def reduced():
    return ArchConfig(
        name="yi-6b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab=256,
        blocks=CONFIG.blocks,
        params_dtype="float32", compute_dtype="float32")
