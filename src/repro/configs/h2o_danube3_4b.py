"""h2o-danube-3-4b [dense]: 24L d=3840 32H (GQA kv=8) d_ff=10240 vocab=32000 —
llama+mistral mix with sliding-window attention. [arXiv:2401.16818; unverified]

SWA (window 4096) makes decode memory O(window): eligible for long_500k.
"""

from repro.models.config import ArchConfig, Block

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    blocks=(Block("swa", "mlp"),),
    swa_window=4096,
    rope_theta=500_000.0,
    optimizer="adamw",
    fsdp=False,
    microbatches_train_4k=2,
    sub_quadratic=True,        # O(window) attention
    remat_group=8,
)


def reduced():
    return ArchConfig(
        name="h2o-danube-3-4b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab=256,
        blocks=CONFIG.blocks, swa_window=8,
        params_dtype="float32", compute_dtype="float32")
