"""Launchers: mesh construction + the integrate/sweep CLI entry points."""
