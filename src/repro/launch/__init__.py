"""Launchers: mesh construction + the integrate/sweep/serve CLI entry
points."""
