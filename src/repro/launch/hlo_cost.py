"""Trip-count-aware cost model over compiled (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a while-loop body ONCE,
so any scanned program (layer stacks, microbatch accumulation, blocked
attention, vegas chunk loops) under-reports FLOPs/bytes by the trip count.
This parser walks the optimized HLO, multiplies every computation by the
product of enclosing ``known_trip_count`` annotations, and produces:

  flops            — 2*M*N*K for every dot (+conv), trip-aware
  hbm_bytes        — HBM traffic model: per top-level op, operand+output
                     sizes (fusion internals excluded: they live in VMEM)
  collective_bytes — per collective kind, trip-aware (feeds the ICI term)

Dots dominate the compute term on TPU (MXU); elementwise flops ride along in
fusions and are deliberately not counted (they are free relative to the MXU
at the shapes in question).
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\](?:\{[^}]*\})?")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^(\([^()]*\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|body|condition|branch_computations|to_apply)="
                      r"(\{[^}]*\}|%?[\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "iota", "broadcast", "reshape",
               "transpose", "convert", "copy-start", "copy-done"}


def _parse_shape(text):
    """Returns list of (dtype, [dims]) for a shape or tuple-shape string."""
    return [(m.group(1), [int(d) for d in m.group(2).split(",") if d])
            for m in _SHAPE_RE.finditer(text)]


def _shape_bytes(text):
    total = 0
    for dt, dims in _parse_shape(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


class HloCost:
    def __init__(self, hlo_text: str):
        self.computations = {}       # name -> list of parsed ops
        self.entry = None            # name of the ENTRY computation
        self._parse(hlo_text)

    _DEF_START = re.compile(r"^(ROOT\s+)?%[\w.\-]+\s*=")
    _HDR_START = re.compile(r"^(ENTRY\s+)?%[\w.\-]+\s*\(")

    @classmethod
    def _logical_lines(cls, text):
        """Merge physical lines into logical op definitions (the HLO printer
        wraps long tuple types across lines) and strip /*...*/ comments."""
        out, buf = [], ""
        for raw in text.splitlines():
            s = raw.strip()
            if not s:
                continue
            if cls._DEF_START.match(s) or cls._HDR_START.match(s) or s == "}":
                if buf:
                    out.append(buf)
                buf = s
            else:
                buf += " " + s
        if buf:
            out.append(buf)
        return [re.sub(r"/\*.*?\*/", "", l) for l in out]

    def _parse(self, text):
        cur = None
        for line in self._logical_lines(text):
            if not line.strip():
                continue
            mcomp = _COMP_RE.match(line.strip()) if line.rstrip().endswith("{") else None
            if mcomp:
                cur = mcomp.group(1)
                self.computations[cur] = []
                if line.strip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if line.strip() == "}":
                continue
            if cur is None:
                continue
            mdef = _DEF_RE.match(line)
            if not mdef:
                continue
            name, rest = mdef.groups()
            mop = _OP_RE.match(rest)
            if not mop:
                continue
            shape_txt, opcode, tail = mop.groups()
            calls = []
            for mc in _CALL_RE.finditer(tail):
                tgt = mc.group(1)
                if tgt.startswith("{"):
                    calls += [t.strip().lstrip("%") for t in tgt[1:-1].split(",")]
                else:
                    calls.append(tgt.lstrip("%"))
            trip = 1
            mt = _TRIP_RE.search(tail)
            if opcode == "while":
                trip = int(mt.group(1)) if mt else 1
            op = {"name": name, "opcode": opcode, "shape": shape_txt,
                  "tail": tail, "calls": calls, "trip": trip}
            self.computations[cur].append(op)
            self.computations[cur + "::" + name] = op  # symbol table entry

    def _sym_shape(self, comp, operand_name):
        op = self.computations.get(comp + "::" + operand_name)
        return op["shape"] if op else None

    def _operands(self, comp, op):
        """Operand shape strings (from the computation's symbol table)."""
        args = op["tail"].split(")")[0]
        shapes = []
        for a in args.split(","):
            a = a.strip().lstrip("%")
            if not a:
                continue
            s = self._sym_shape(comp, a)
            if s:
                shapes.append((a, s))
        return shapes

    def _dot_flops(self, comp, op):
        out = _parse_shape(op["shape"])
        out_elems = 1
        for _, dims in out:
            for d in dims:
                out_elems *= d
        mk = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op["tail"])
        kdims = [int(x) for x in mk.group(1).split(",")] if mk and mk.group(1) else []
        ops = self._operands(comp, op)
        k = 1
        if ops and kdims:
            lhs_shape = _parse_shape(ops[0][1])
            if lhs_shape:
                dims = lhs_shape[0][1]
                for i in kdims:
                    if i < len(dims):
                        k *= dims[i]
        return 2.0 * out_elems * k

    def analyze(self, entry=None):
        # find entry computation: the one never called -> assume first
        called = set()
        for cname, items in self.computations.items():
            if "::" in cname:
                continue
            for op in items:
                called.update(op["calls"])
        roots = [c for c in self.computations if "::" not in c and c not in called]
        entry = entry or self.entry or (roots[0] if roots else None)

        flops = 0.0
        hbm = 0.0
        coll = defaultdict(float)
        visited_stack = []

        def walk(comp, mult, top_level=True):
            nonlocal flops, hbm
            if comp not in self.computations or comp in visited_stack:
                return
            visited_stack.append(comp)
            for op in self.computations[comp]:
                if not isinstance(op, dict):
                    continue
                oc = op["opcode"]
                if oc in ("dot", "convolution"):
                    flops += self._dot_flops(comp, op) * mult
                if top_level and oc not in _SKIP_BYTES:
                    if oc == "dynamic-update-slice":
                        # in-place slice write: count the update, not the buffer
                        ops_ = self._operands(comp, op)
                        upd = _shape_bytes(ops_[1][1]) if len(ops_) > 1 else 0
                        hbm += 2.0 * upd * mult
                    else:
                        out_b = _shape_bytes(op["shape"])
                        in_b = sum(_shape_bytes(s) for _, s in
                                   self._operands(comp, op))
                        hbm += (out_b + in_b) * mult
                for c in COLLECTIVES:
                    if oc == c or oc == f"{c}-start":
                        coll[c] += _shape_bytes(op["shape"]) * mult
                child_mult = mult * (op["trip"] if op["opcode"] == "while" else 1)
                for callee in op["calls"]:
                    # fusion internals are VMEM-resident: not top-level
                    walk(callee, child_mult,
                         top_level=(op["opcode"] in ("while", "conditional",
                                                     "call")))
            visited_stack.pop()

        if entry:
            walk(entry, 1.0)
        return {"flops": flops, "hbm_bytes": hbm,
                "collectives": dict(coll)}


def analyze_text(hlo_text: str) -> dict:
    return HloCost(hlo_text).analyze()
