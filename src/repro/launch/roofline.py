"""Roofline report: turns launch_results/dryrun.json into the §Roofline
table (EXPERIMENTS.md).

Per (arch x shape x mesh) cell, from the compiled dry-run artifact:
  compute_s    = HLO_dot_FLOPs / peak            (197 TFLOP/s bf16, v5e)
  memory_s     = HLO_HBM_bytes / bw              (819 GB/s)
  collective_s = collective_bytes / link_bw      (~50 GB/s/link ICI)
All three are per-device, per-step, trip-count-aware (launch/hlo_cost.py).
MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (prefill/decode), i.e.
the textbook useful-work count; MODEL/HLO ratio surfaces remat + causal
over-compute + dispatch overhead.
"""

from __future__ import annotations

import json

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s
LINK_BW = 50e9          # bytes/s/link

SHAPE_TOKENS = {
    "train_4k": ("train", 4096 * 256),
    "prefill_32k": ("prefill", 32768 * 32),
    "decode_32k": ("decode", 128),
    "long_500k": ("decode", 1),
}


def model_flops_per_device(arch, shape, n_devices):
    from repro import configs
    if arch == "vegas":
        return None
    cfg = configs.get(arch)
    kind, tokens = SHAPE_TOKENS[shape]
    n = cfg.active_param_count()
    mult = 6 if kind == "train" else 2
    return mult * n * tokens / n_devices


def analyze_record(rec):
    n_dev = 512 if rec["mesh"] == "multi" else 256
    flops = rec.get("flops") or 0.0
    hbm = rec.get("hbm_bytes") or 0.0
    coll = sum((rec.get("collectives") or {}).values())
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = coll / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec.get("shape", ""), n_dev) \
        if rec["arch"] != "vegas" else None
    ratio = (mf / flops) if (mf and flops) else None
    # roofline fraction: useful FLOPs per second achievable if the step runs
    # at the max of the three terms (the bound), vs peak.
    bound = max(terms.values())
    frac = (mf / bound / PEAK_FLOPS) if (mf and bound > 0) else \
        (compute_s / bound if bound > 0 else None)
    return dict(terms=terms, bottleneck=bottleneck, model_flops=mf,
                useful_ratio=ratio, roofline_fraction=frac)


def markdown_table(path="launch_results/dryrun.json", mesh="single"):
    with open(path) as f:
        data = json.load(f)
    rows = []
    hdr = ("| arch | shape | compute s | memory s | collective s | bound | "
           "MODEL/HLO | roofline frac | fits 16GB | one-line fix |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for r in data:
        if r["mesh"] != mesh:
            continue
        if r.get("ok") is None:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — "
                        f"| — | skipped: {r.get('skip','')} |")
            continue
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | | |")
            continue
        a = analyze_record(r)
        t = a["terms"]
        mem_fit = ((r.get("temp_size_in_bytes") or 0)
                   + (r.get("argument_size_in_bytes") or 0)) / 1e9
        fix = suggest_fix(r, a)
        ratio = f"{a['useful_ratio']:.2f}" if a["useful_ratio"] else "n/a"
        frac = (f"{a['roofline_fraction']:.3f}"
                if a["roofline_fraction"] is not None else "n/a")
        fit = f"{mem_fit:.1f} GB" + ("" if mem_fit < 16 else " (!)")
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {t['compute']:.3e} | {t['memory']:.3e} | {t['collective']:.3e} "
            f"| {a['bottleneck']} | {ratio} | {frac} | {fit} | {fix} |")
    return "\n".join(rows)


def suggest_fix(rec, a):
    b = a["bottleneck"]
    if b == "memory":
        return ("blocked/flash attention or fp8 activations to cut HBM "
                "traffic of the dominant S×S / logits buffers")
    if b == "collective":
        return ("overlap all-gather with compute (latency-hiding) or shrink "
                "FSDP gather granularity")
    return "already compute-bound: raise MODEL/HLO by trimming remat recompute"


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default="launch_results/dryrun.json")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    print(markdown_table(args.path, args.mesh))
