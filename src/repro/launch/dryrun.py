import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import/init: jax locks the device count on first use.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against 512 placeholder host devices, and extract the roofline
terms (HLO FLOPs/bytes from cost_analysis, collective bytes parsed from the
post-SPMD optimized HLO).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out results.json]
  python -m repro.launch.dryrun --vegas            # the paper's own engine

Results are appended (resumably) to launch_results/dryrun.json.
"""

import argparse
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_production_mesh, dp_axes
from repro.models import sharding as SH
from repro.models import transformer as T
from repro.train import optimizer as OPT
from repro.train.train_step import make_train_step
from repro.serve.decode import serve_step

SHAPES = {
    "train_4k":   dict(kind="train",   seq=4096,    batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768,   batch=32),
    "decode_32k": dict(kind="decode",  seq=32768,   batch=128),
    "long_500k":  dict(kind="long",    seq=524288,  batch=1),
}

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "launch_results", "dryrun.json")


# ----------------------------------------------------------- input specs ----

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def input_specs(arch: str, shape: str, mesh):
    """ShapeDtypeStruct stand-ins for every model input of this cell:
    weak-type-correct, shardable, no device allocation."""
    cfg = configs.get(arch)
    info = SHAPES[shape]
    dp = dp_axes(mesh)
    b, s = info["batch"], info["seq"]
    out = {"tokens": _sds((b, s), jnp.int32, mesh, P(dp, None))}
    if info["kind"] == "train":
        out["labels"] = _sds((b, s), jnp.int32, mesh, P(dp, None))
    if cfg.xattn_memory_len and info["kind"] in ("train", "prefill"):
        out["memory"] = _sds((b, cfg.xattn_memory_len, cfg.d_model),
                             jnp.dtype(cfg.compute_dtype), mesh, P(dp, None, None))
    return out


def _tree_sds(tree_shapes, tree_specs, mesh):
    tree_specs = SH.sanitize_specs(tree_specs, tree_shapes, mesh)
    return jax.tree.map(
        lambda sh, sp: _sds(sh.shape, sh.dtype, mesh, sp),
        tree_shapes, tree_specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))


def build_cell(arch: str, shape: str, mesh):
    """Returns (fn, args_sds) ready for jax.jit(fn).lower(*args_sds)."""
    cfg = configs.get(arch)
    info = SHAPES[shape]
    dp = dp_axes(mesh)
    kind = info["kind"]
    b, s = info["batch"], info["seq"]

    if kind == "long" and not cfg.sub_quadratic:
        raise ValueError("skip")
    SH.set_mesh_context(mesh, dp_axes=dp)

    pspecs = SH.param_specs(cfg)
    pshapes = jax.eval_shape(lambda k: T.init_params(k, cfg),
                             jax.random.PRNGKey(0))
    params_sds = _tree_sds(pshapes, pspecs, mesh)

    if kind == "train":
        opt = OPT.for_config(cfg)
        ospecs = (SH.opt_specs_adafactor(pspecs, pshapes)
                  if cfg.optimizer == "adafactor" else SH.opt_specs_adam(pspecs))
        oshapes = jax.eval_shape(
            lambda ps: opt.init(ps), pshapes)
        opt_sds = _tree_sds(oshapes, ospecs, mesh)
        batch_sds = input_specs(arch, shape, mesh)
        step = make_train_step(cfg, opt, n_micro=cfg.microbatches_train_4k,
                               mesh=mesh, dp_axes=dp,
                               param_specs=SH.sanitize_specs(pspecs, pshapes,
                                                             mesh))
        fn = lambda state, batch: step(state, batch)
        return fn, ({"params": params_sds, "opt": opt_sds}, batch_sds)

    if kind == "prefill":
        ins = input_specs(arch, shape, mesh)
        mem = ins.get("memory")

        def fn(params, tokens, memory=None):
            return T.prefill(params, tokens, cfg, cache_len=s, memory=memory)
        if mem is not None:
            return fn, (params_sds, ins["tokens"], mem)
        return functools.partial(fn, memory=None), (params_sds, ins["tokens"])

    # decode shapes
    cache_kind = "decode" if kind == "decode" else "long"
    cspecs = SH.cache_specs(cfg, cache_kind, dp_axes=dp)
    cshapes = jax.eval_shape(
        lambda: T.init_cache(cfg, b, s, dtype=jnp.bfloat16))
    cache_sds = _tree_sds(cshapes, cspecs, mesh)
    tok_sds = _sds((b,), jnp.int32, mesh,
                   P(dp) if kind == "decode" else P())
    pos_sds = _sds((), jnp.int32, mesh, P())

    def fn(params, cache, token, pos):
        return serve_step(params, cache, token, pos, cfg)

    return fn, (params_sds, cache_sds, tok_sds, pos_sds)


# ----------------------------------------------- vegas cells (the paper) ----

def build_vegas_cell(mesh, *, neval=2**26, dim=8, name="vegas_fill"):
    """The paper's own workload on the production mesh: one VEGAS+ iteration
    (fill + adapt) sharded over every mesh axis."""
    from repro.core import integrator as I
    from repro.core.integrands import make_ridge
    from repro.dist.sharded_fill import make_sharded_fill

    ig = make_ridge(dim=dim, n_peaks=100)
    cfg = I.VegasConfig(neval=neval, max_it=2, ninc=1024,
                        chunk=1 << 14).resolve(ig.dim)
    fill_fn = make_sharded_fill(mesh, mesh.axis_names, cfg)
    step = functools.partial(I.iteration_step, integrand=ig, cfg=cfg,
                             fill_fn=fill_fn)
    st_shapes = jax.eval_shape(
        lambda k: I.init_state(ig, cfg, k), jax.random.PRNGKey(0))
    st_sds = jax.tree.map(
        lambda sh: _sds(sh.shape, sh.dtype, mesh, P()), st_shapes)
    return step, (st_sds,)


# ------------------------------------------------------------- analysis ----

_COLL_RE = re.compile(
    r"(\w+[\w.-]*)\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s64|u64|s16|u16|pred|s8|u8)"
                       r"\[([\d,]*)\]")

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
          "s64": 8, "u64": 8, "s16": 2, "u16": 2, "pred": 1, "s8": 1, "u8": 1}


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the optimized HLO,
    keyed by op kind."""
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, shape_txt, kind = m.groups()
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_txt)
    return out


def analyze(compiled) -> dict:
    res = {}
    try:
        mem = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                res[k] = int(v)
    except Exception as e:  # pragma: no cover
        res["memory_error"] = str(e)
    try:
        # NOTE: xla's cost_analysis counts while bodies ONCE (trip counts
        # ignored) — kept for reference only; the roofline uses hlo_cost.
        ca = compiled.cost_analysis()
        res["xla_flops_once"] = float(ca.get("flops", -1))
    except Exception as e:  # pragma: no cover
        res["cost_error"] = str(e)
    try:
        from repro.launch import hlo_cost
        hc = hlo_cost.analyze_text(compiled.as_text())
        res["flops"] = hc["flops"]
        res["hbm_bytes"] = hc["hbm_bytes"]
        res["collectives"] = hc["collectives"]
    except Exception as e:  # pragma: no cover
        res["hlo_cost_error"] = str(e)
    return res


def run_cell(arch, shape, mesh_name, out_path):
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name}
    t0 = time.time()
    try:
        if arch == "vegas":
            fn, args = build_vegas_cell(mesh)
        else:
            fn, args = build_cell(arch, shape, mesh)
        lowered = jax.jit(fn).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        rec.update(analyze(compiled))
        rec["ok"] = True
        mem_line = rec.get("temp_size_in_bytes")
        print(f"[OK] {arch} x {shape} x {mesh_name}: "
              f"lower {rec['lower_s']}s compile {rec['compile_s']}s "
              f"flops={rec.get('flops', 0):.3e} temp={mem_line}")
    except ValueError as e:
        if str(e) == "skip":
            rec["ok"] = None
            rec["skip"] = "long_500k requires sub-quadratic attention"
            print(f"[SKIP] {arch} x {shape}: not sub-quadratic")
        else:
            rec["ok"] = False
            rec["error"] = traceback.format_exc()[-2000:]
            print(f"[FAIL] {arch} x {shape} x {mesh_name}: {e}")
    except Exception as e:
        rec["ok"] = False
        rec["error"] = traceback.format_exc()[-2000:]
        print(f"[FAIL] {arch} x {shape} x {mesh_name}: {type(e).__name__}: {e}")
    _append(out_path, rec)
    return rec


def _append(path, rec):
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    data = []
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data = [r for r in data
            if not (r["arch"] == rec["arch"] and r["shape"] == rec["shape"]
                    and r["mesh"] == rec["mesh"])]
    data.append(rec)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1)
    os.replace(tmp, path)


def done_cells(path):
    path = os.path.abspath(path)
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        return {(r["arch"], r["shape"], r["mesh"]) for r in json.load(f)
                if r.get("ok") is not False}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--vegas", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.vegas:
        cells = [("vegas", "fill_2e26")]
    elif args.all:
        for a in configs.ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells = [(args.arch, args.shape)]

    done = done_cells(args.out)
    for a, s in cells:
        for m in meshes:
            if (a, s, m) in done:
                print(f"[CACHED] {a} x {s} x {m}")
                continue
            run_cell(a, s, m, args.out)


if __name__ == "__main__":
    main()
