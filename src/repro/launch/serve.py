"""Long-lived sweep-service CLI (DESIGN.md §12).

  PYTHONPATH=src python -m repro.launch.serve --demo 8 --rtol 0.05
  PYTHONPATH=src python -m repro.launch.serve --requests reqs.jsonl \
      --cache maps.npz --stats-json stats.json

Runs a `repro.serve.SweepService` with its background micro-batching
worker and drives it with either a generated demo burst (``--demo N``
gaussian requests) or a JSONL file (``--requests``, one
`IntegrationRequest` object per line, e.g.
``{"family": "gaussian", "params": [0.3], "rtol": 0.01, "seed": 7}``).
Rejected requests print their one-line PlanError; served requests print
their estimates and billing record; the run ends with the ``stats()``
snapshot (``--stats-json`` writes it for dashboards).
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.engine import PlanError
from repro.launch import env
from repro.serve import IntegrationRequest, SweepService


def _load_requests(path: str) -> list[IntegrationRequest]:
    out = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            try:
                obj = json.loads(line)
                fkw = obj.get("family_kwargs")
                if isinstance(fkw, dict):
                    obj["family_kwargs"] = tuple(sorted(fkw.items()))
                out.append(IntegrationRequest(**obj))
            except (json.JSONDecodeError, TypeError) as e:
                raise SystemExit(f"{path}:{lineno}: bad request: {e}")
    return out


def _demo_burst(args) -> list[IntegrationRequest]:
    params = np.linspace(0.2, 0.8, args.demo)
    return [IntegrationRequest(
        family=args.family, params=[float(p)], rtol=args.rtol,
        atol=args.atol, time_budget_s=args.time_budget, seed=i,
        neval=args.neval, max_it=args.iters,
        accum_dtype=args.accum_dtype) for i, p in enumerate(params)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--demo", type=int, default=0, metavar="N",
                     help="submit a burst of N single-scenario demo "
                          "requests")
    src.add_argument("--requests", default=None, metavar="FILE.jsonl",
                     help="serve one JSON request per line")
    ap.add_argument("--family", default="gaussian")
    ap.add_argument("--rtol", type=float, default=0.0)
    ap.add_argument("--atol", type=float, default=0.0)
    ap.add_argument("--time-budget", type=float, default=None,
                    help="per-request wall-clock budget (seconds)")
    ap.add_argument("--neval", type=int, default=20_000)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--accum-dtype", choices=["float32", "float64"],
                    default=None,
                    help="demo requests' §15 accumulation dtype (float64 "
                         "needs --x64; JSONL requests carry their own "
                         "accum_dtype field)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="scenarios per coalesced micro-batch")
    ap.add_argument("--max-wait", type=float, default=0.02,
                    help="micro-batching window (seconds)")
    ap.add_argument("--cache", default=None,
                    help="shared map-pool path (.npz; warm starts persist "
                         "across service restarts and CLI sweeps)")
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="per-request result timeout (seconds)")
    ap.add_argument("--stats-json", default=None, metavar="OUT.json",
                    help="write the final stats() snapshot")
    ap.add_argument("--cost-table", default=None, metavar="PATH",
                    help="calibrated cost table (engine.autotune) used as "
                         "the budget-calibration prior for classes the "
                         "service has not yet observed")
    env.add_env_args(ap)
    args = ap.parse_args(argv)
    env.apply_env_args(args)

    if args.requests:
        requests = _load_requests(args.requests)
    else:
        if args.demo <= 0:
            args.demo = 4
        requests = _demo_burst(args)

    with SweepService(max_batch=args.max_batch, max_wait_s=args.max_wait,
                      cache=args.cache, cost_table=args.cost_table) as svc:
        tickets = []
        for req in requests:
            try:
                tickets.append(svc.submit(req))
            except PlanError as e:
                print(f"REJECTED {req.family}: {e}")
        for t in tickets:
            r = t.result(timeout=args.timeout)
            print(r)
            for j in range(r.n_scenarios):
                line = (f"  [{j}] {r.mean[j]:.8g} +- {r.sdev[j]:.3g} "
                        f"(it {r.n_it_used[j]}/{r.it_cap[j]})")
                if r.targets is not None:
                    pull = ((r.mean[j] - r.targets[j])
                            / max(float(r.sdev[j]), 1e-30))
                    line += f"  target={r.targets[j]:.8g} pull={pull:+.2f}"
                print(line)

    stats = svc.stats()
    print(f"served {stats['requests']['completed']} requests / "
          f"{stats['requests']['scenarios_completed']} scenarios in "
          f"{stats['batches']['count']} batches "
          f"(mean occupancy {stats['batches']['mean_occupancy']:.1f}, "
          f"cache hit rate {stats['cache']['hit_rate']:.0%}, "
          f"{stats['throughput']['requests_per_s']:.1f} req/s)")
    print(f"billed {stats['iterations']['billed']} scenario-iterations, "
          f"saved {stats['iterations']['saved_vs_max_it']} vs max_it, "
          f"{stats['iterations']['capped_scenarios']} budget-capped")
    if args.stats_json:
        with open(args.stats_json, "w") as f:
            json.dump(stats, f, indent=1)
        print(f"# wrote {args.stats_json}", file=sys.stderr)
    return stats


if __name__ == "__main__":
    main()
