"""Production mesh: 16x16 (one v5e pod, 256 chips) or 2x16x16 (2 pods).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets the host-device-count override before any
jax initialization)."""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def dp_axes(mesh) -> tuple:
    """Data-parallel axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_local_mesh():
    """Whatever devices exist locally, as a 1D data mesh (tests/examples)."""
    n = jax.device_count()
    return jax.make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
