"""Mesh construction.

FUNCTIONS, not module constants: importing this module must never touch jax
device state (multi-device tests set the host-device-count override before
any jax initialization).

``make_mesh`` papers over a jax API gap: ``jax.sharding.AxisType`` (and the
``axis_types=`` kwarg of ``jax.make_mesh``) only exists on newer jax; on
older versions every mesh axis is implicitly Auto, which is exactly what we
want, so the kwarg is simply dropped.  All mesh construction in this repo
(tests, examples, benches) goes through this one shim."""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType as _AxisType
except ImportError:  # older jax: all axes behave as Auto
    _AxisType = None


def make_mesh(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types across jax versions."""
    if _AxisType is not None:
        return jax.make_mesh(shape, axis_names,
                             axis_types=(_AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


def make_local_mesh():
    """Whatever devices exist locally, as a 1D data mesh (tests/examples).
    This is the mesh ``dist.sharded_fill.make_sharded_fill`` expects for
    single-host multi-device runs (DESIGN.md §5)."""
    return make_mesh((jax.device_count(),), ("data",))
