"""CLI driver for the VEGAS+ engine (the paper's workload).

  PYTHONPATH=src python -m repro.launch.integrate --integrand ridge \
      --neval 1000000 --iters 20 --config def
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.core import VegasConfig, run
from repro.core import integrands as igs
from repro.configs.vegas import PAPER_CONFIGS

INTEGRANDS = {
    "sine_exp": igs.make_sine_exp,
    "linear": igs.make_linear,
    "cosine": igs.make_cosine,
    "exponential": igs.make_exponential,
    "roos_arnold": igs.make_roos_arnold,
    "morokoff_caflisch": igs.make_morokoff_caflisch,
    "gaussian": igs.make_gaussian,
    "ridge": igs.make_ridge,
    "asian": igs.make_asian_option,
    "asian_geo": lambda: igs.make_asian_option(geometric=True),
    "feynman": igs.make_feynman_path,
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--integrand", choices=list(INTEGRANDS), default="ridge")
    ap.add_argument("--neval", type=int, default=500_000)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--skip", type=int, default=5)
    ap.add_argument("--config", choices=["def", "vf", "tq"], default="def")
    ap.add_argument("--backend", choices=["ref", "pallas"], default="ref")
    ap.add_argument("--interpret", choices=["auto", "true", "false"],
                    default="auto",
                    help="pallas execution mode; auto = compiled on TPU, "
                         "interpreter elsewhere (kernels.backend_default)")
    ap.add_argument("--no-fused", dest="fused", action="store_false",
                    help="pallas: use the P-V2 baseline kernel instead of "
                         "the P-V3 fused streaming kernel")
    ap.add_argument("--tile", type=int, default=None,
                    help="pallas tile override (default: VMEM autotune)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    ig = INTEGRANDS[args.integrand]()
    base = PAPER_CONFIGS[args.config]
    interpret = {"auto": None, "true": True, "false": False}[args.interpret]
    cfg = VegasConfig(neval=args.neval, max_it=args.iters, skip=args.skip,
                      ninc=base.ninc, alpha=base.alpha, beta=base.beta,
                      backend=args.backend, interpret=interpret,
                      fused_cubes=args.fused, tile=args.tile)
    t0 = time.time()
    res = run(ig, cfg, key=jax.random.PRNGKey(args.seed))
    dt = time.time() - t0
    print(f"integrand={ig.name} dim={ig.dim} config={args.config}")
    print(f"  result  = {res.mean:.8g} +- {res.sdev:.3g} "
          f"(chi2/dof {res.chi2_dof:.2f}, {res.n_it} iterations)")
    if ig.target is not None:
        pull = (res.mean - ig.target) / max(res.sdev, 1e-30)
        print(f"  target  = {ig.target:.8g}  pull = {pull:+.2f} sigma")
    print(f"  wall    = {dt:.2f}s  ({args.neval * args.iters / dt:,.0f} evals/s)")
    return res


if __name__ == "__main__":
    main()
