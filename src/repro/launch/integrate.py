"""CLI driver for the VEGAS+ engine (the paper's workload).

  PYTHONPATH=src python -m repro.launch.integrate --integrand ridge \
      --neval 1000000 --iters 20 --config def --backend pallas-fused

Execution axes (backend / sharding / checkpointing / stopping) map 1:1 onto
the unified ``repro.engine.ExecutionConfig``; ``--rtol``/``--atol`` set a
`StopPolicy` convergence target (the run stops once the combined sdev meets
it, reported as ``n_it_used``); ``--plan`` prints the validated plan
(backend capabilities, shard count, loop mode) without running it.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs.vegas import PAPER_CONFIGS
from repro.core import VegasConfig
from repro.core import integrands as igs
from repro.engine import (CheckpointPolicy, ExecutionConfig, GradPolicy,
                          PrecisionPolicy, StopPolicy, available, execute,
                          make_plan)
from repro.launch import env

INTEGRANDS = {
    "sine_exp": igs.make_sine_exp,
    "linear": igs.make_linear,
    "cosine": igs.make_cosine,
    "exponential": igs.make_exponential,
    "roos_arnold": igs.make_roos_arnold,
    "morokoff_caflisch": igs.make_morokoff_caflisch,
    "gaussian": igs.make_gaussian,
    "ridge": igs.make_ridge,
    "asian": igs.make_asian_option,
    "asian_geo": lambda: igs.make_asian_option(geometric=True),
    "feynman": igs.make_feynman_path,
}


def add_execution_args(ap: argparse.ArgumentParser) -> None:
    """The shared execution-axis flags (integrate + sweep CLIs)."""
    ap.add_argument("--backend",
                    choices=sorted(available()) + ["auto"], default="ref",
                    help="fill backend from the engine registry "
                         "(pallas-fused = P-V3 streaming kernel, pallas-gpu "
                         "= Triton scatter kernel; auto = platform default "
                         "via kernels.backend_default)")
    ap.add_argument("--interpret", choices=["auto", "true", "false"],
                    default="auto",
                    help="pallas execution mode; auto = compiled on the "
                         "kernel's native platform (Mosaic on TPU, Triton "
                         "on GPU), interpreter elsewhere "
                         "(kernels.resolve_interpret)")
    ap.add_argument("--tile", type=int, default=None,
                    help="pallas TPU tile override (default: VMEM autotune)")
    ap.add_argument("--block", type=int, default=None,
                    help="pallas-gpu evals per program (default: "
                         "shared-memory autotune, gpu_fill.autotune_block)")
    ap.add_argument("--num-warps", type=int, default=None,
                    help="pallas-gpu Triton num_warps override")
    ap.add_argument("--accum-dtype", choices=["float32", "float64"],
                    default=None,
                    help="accumulation dtype (§15 PrecisionPolicy): widen "
                         "the moment accumulators without changing the "
                         "sample dtype (float64 needs JAX_ENABLE_X64=1 / "
                         "--x64; validated at plan time against the "
                         "backend's declared precision pairs)")
    ap.add_argument("--autotune", action="store_true",
                    help="pick chunk/tile/batch/shard knobs from the "
                         "measured cost model (engine.autotune, §13); "
                         "combine with --plan to see the chosen knobs and "
                         "predicted vs default cost without running")
    ap.add_argument("--cost-table", default=None, metavar="PATH",
                    help="calibrated cost table for --autotune (default: "
                         "$REPRO_COST_TABLE, then ./COST_TABLE.json, then "
                         "the builtin order-of-magnitude table)")
    ap.add_argument("--shard", action="store_true",
                    help="shard the fill over all local devices "
                         "(launch.mesh.make_local_mesh)")
    ap.add_argument("--rtol", type=float, default=0.0,
                    help="stop once combined sdev <= rtol * |mean| "
                         "(adaptive while_loop; 0 = fixed-length loop)")
    ap.add_argument("--atol", type=float, default=0.0,
                    help="stop once combined sdev <= atol "
                         "(combines with --rtol as max(rtol*|mean|, atol))")
    ap.add_argument("--min-it", type=int, default=2,
                    help="never stop before this many iterations")
    ap.add_argument("--grad", choices=["off", "pathwise", "score"],
                    default="off",
                    help="differentiable two-phase run (repro.grad, §11): "
                         "adapt with gradients stopped, then a frozen-map "
                         "eval pass; reports d(estimate)/d(params, bounds)")
    ap.add_argument("--no-grad-sdev", action="store_true",
                    help="skip the per-component gradient error bars "
                         "(the derivative-integrand passes)")
    ap.add_argument("--plan", action="store_true",
                    help="print the validated execution plan and exit")
    env.add_env_args(ap)


def build_execution(args, **extra) -> ExecutionConfig:
    # interpret/tile/block/num_warps are forwarded as given; the plan
    # validator rejects them loudly when the chosen backend declares no
    # such knob.
    interpret = {"auto": None, "true": True, "false": False}[args.interpret]
    mesh = None
    if args.shard:
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh()
    # Any nonzero tolerance builds a policy — including a negative typo,
    # which must reach make_plan's non-negative validation (PlanError),
    # not be silently dropped here.
    stop = (StopPolicy(rtol=args.rtol, atol=args.atol, min_it=args.min_it)
            if (args.rtol != 0 or args.atol != 0) else None)
    grad = (GradPolicy(mode=args.grad, with_sdev=not args.no_grad_sdev)
            if args.grad != "off" else None)
    precision = (PrecisionPolicy(accum_dtype=args.accum_dtype)
                 if getattr(args, "accum_dtype", None) else None)
    return ExecutionConfig(backend=args.backend, interpret=interpret,
                           tile=args.tile, block=args.block,
                           num_warps=args.num_warps, mesh=mesh, stop=stop,
                           grad=grad, autotune=args.autotune,
                           cost_table=args.cost_table, precision=precision,
                           **extra)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--integrand", choices=list(INTEGRANDS), default="ridge")
    ap.add_argument("--neval", type=int, default=500_000)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--skip", type=int, default=5)
    ap.add_argument("--config", choices=["def", "vf", "tq"], default="def")
    ap.add_argument("--checkpoint", default=None, metavar="DIR",
                    help="checkpoint VegasState into DIR every iteration "
                         "(forces the host loop)")
    ap.add_argument("--seed", type=int, default=0)
    add_execution_args(ap)
    args = ap.parse_args(argv)
    env.apply_env_args(args)

    ig = INTEGRANDS[args.integrand]()
    base = PAPER_CONFIGS[args.config]
    execution = build_execution(
        args, checkpoint=(CheckpointPolicy(directory=args.checkpoint)
                          if args.checkpoint else None))
    cfg = VegasConfig(neval=args.neval, max_it=args.iters, skip=args.skip,
                      ninc=base.ninc, alpha=base.alpha, beta=base.beta,
                      execution=execution)
    plan = make_plan(ig, cfg)
    if args.plan:
        print(plan.describe())
        return plan
    t0 = time.time()
    res = execute(plan, key=jax.random.PRNGKey(args.seed))
    dt = time.time() - t0
    print(f"integrand={ig.name} dim={ig.dim} config={args.config} "
          f"[{execution.describe()}]")
    if plan.grad is not None:
        # GradResult: the frozen-map eval estimate + boundary sensitivities.
        print(f"  result  = {res.mean:.8g} +- {res.sdev:.3g} "
              f"(mode={res.mode}, {res.n_it_used} adapt iterations)")
        for j in range(ig.dim):
            print(f"  d/d bounds[{j}]  lower {res.grad_lower[j]:+.5g}  "
                  f"upper {res.grad_upper[j]:+.5g}")
    else:
        print(f"  result  = {res.mean:.8g} +- {res.sdev:.3g} "
              f"(chi2/dof {res.chi2_dof:.2f}, {res.n_it} combined, "
              f"{res.n_it_used}/{args.iters} iterations executed)")
    if ig.target is not None:
        pull = (res.mean - ig.target) / max(res.sdev, 1e-30)
        print(f"  target  = {ig.target:.8g}  pull = {pull:+.2f} sigma")
    print(f"  wall    = {dt:.2f}s  ({args.neval * args.iters / dt:,.0f} evals/s)")
    return res


if __name__ == "__main__":
    main()
