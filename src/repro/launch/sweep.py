"""Batched scenario-sweep CLI: B integrands, one jitted program.

  PYTHONPATH=src python -m repro.launch.sweep --family asian --batch 8 \
      --neval 100000 --iters 10 [--compare-serial] [--cache maps.npz] \
      [--backend pallas-fused] [--shard]

Sweeps a parameterized integrand family (repro.batch.family.FAMILIES)
through the unified execution engine.  ``--shard`` composes the batch axis
with the mesh axis — B scenarios × D local devices as ONE jitted program
(the sharded batched path, DESIGN.md §9.3); ``--compare-serial`` also times
the B-serial-runs baseline and reports per-scenario agreement; ``--cache``
warm-starts the importance maps from (and refreshes) an on-disk map cache;
``--rtol``/``--atol`` set a per-scenario convergence target — converged
scenarios stop adapting (masked while_loop iterations, §10) and the sweep
reports the scenario-iterations saved.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.batch import MapCache, run_batch, run_serial
from repro.batch.family import FAMILIES
from repro.core import VegasConfig
from repro.engine import make_plan
from repro.launch import env
from repro.launch.integrate import add_execution_args, build_execution


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", choices=sorted(FAMILIES), default="gaussian")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--neval", type=int, default=100_000)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--skip", type=int, default=3)
    ap.add_argument("--ninc", type=int, default=128)
    ap.add_argument("--chunk", type=int, default=16_384)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache", default=None,
                    help="path to an .npz map cache (warm start + refresh)")
    ap.add_argument("--compare-serial", action="store_true",
                    help="also run the B-serial-calls baseline and compare")
    add_execution_args(ap)
    args = ap.parse_args(argv)
    env.apply_env_args(args)

    family = FAMILIES[args.family](args.batch)
    execution = build_execution(args)
    cfg = VegasConfig(neval=args.neval, max_it=args.iters, skip=args.skip,
                      ninc=args.ninc, chunk=args.chunk, execution=execution)
    if args.plan:
        print(make_plan(family, cfg).describe())
        return None
    key = jax.random.PRNGKey(args.seed)
    cache = MapCache(args.cache) if args.cache else None

    if args.grad != "off":
        # Grad sweep: per-scenario parameter gradients (the Greeks path).
        # The grad program takes no warm-start cache / serial baseline.
        if cache is not None or args.compare_serial:
            ap.error("--grad does not combine with --cache/--compare-serial")
        t0 = time.perf_counter()
        res = run_batch(family, cfg, key=key)
        dt = time.perf_counter() - t0
        print(f"family={family.name} B={res.batch_size} dim={family.dim} "
              f"grad={res.mode} [{execution.describe()}]")
        names = sorted(res.grad) if isinstance(res.grad, dict) else None
        params = np.asarray(jax.tree.leaves(family.params)[0])
        for b in range(res.batch_size):
            line = (f"  [{b}] param={params[b]}  "
                    f"{res.mean[b]:.8g} +- {res.sdev[b]:.3g}")
            if names:
                for n in names:
                    line += f"  d/d{n}={np.asarray(res.grad[n])[b]:+.5g}"
                    if res.grad_sdev is not None:
                        line += f"(+-{np.asarray(res.grad_sdev[n])[b]:.2g})"
            else:
                g = np.asarray(jax.tree.leaves(res.grad)[0][b]).ravel()
                line += "  grad=" + np.array2string(g, precision=4)
            print(line)
        print(f"  grad sweep wall = {dt:.2f}s")
        return res

    t0 = time.perf_counter()
    res = run_batch(family, cfg, key=key, cache=cache)
    dt_batch = time.perf_counter() - t0

    print(f"family={family.name} B={res.batch_size} dim={family.dim} "
          f"neval={args.neval} iters={args.iters} "
          f"warm_start={res.warm_started} [{execution.describe()}]")
    params = np.asarray(jax.tree.leaves(family.params)[0])
    for b in range(res.batch_size):
        p = params[b] if params.ndim == 1 else params[b].tolist()
        line = (f"  [{b}] param={p}  {res.mean[b]:.8g} +- {res.sdev[b]:.3g} "
                f"(chi2/dof {res.chi2_dof[b]:.2f}, "
                f"it {res.n_it_used[b]}/{args.iters})")
        if family.targets is not None:
            pull = (res.mean[b] - family.targets[b]) / max(res.sdev[b], 1e-30)
            line += f"  target={family.targets[b]:.8g} pull={pull:+.2f}"
        print(line)
    print(f"  batched wall = {dt_batch:.2f}s "
          f"({args.neval * args.iters * res.batch_size / dt_batch:,.0f} evals/s)")
    saved = args.iters * res.batch_size - int(res.n_it_used.sum())
    if saved:
        print(f"  early stop saved {saved} of {args.iters * res.batch_size} "
              f"scenario-iterations (per-scenario stop masks)")

    if args.compare_serial:
        t0 = time.perf_counter()
        serial = run_serial(family, cfg, key=key)
        dt_serial = time.perf_counter() - t0
        worst = max(abs(res.mean[b] - serial[b].mean)
                    / max(np.hypot(res.sdev[b], serial[b].sdev), 1e-30)
                    for b in range(res.batch_size))
        print(f"  serial wall  = {dt_serial:.2f}s  "
              f"speedup = {dt_serial / dt_batch:.2f}x  "
              f"worst batched-vs-serial gap = {worst:.3f} combined sigma")
    return res


if __name__ == "__main__":
    main()
