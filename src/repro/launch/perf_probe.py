import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""§Perf iteration probe: recompile one cell with config overrides and print
the three roofline terms + memory, so hypothesis->change->measure cycles are
one command:

  python -m repro.launch.perf_probe --arch mistral-large-123b --shape train_4k \
      --set dense_attn_threshold=2048 microbatches_train_4k=4
"""

import argparse
import dataclasses
import json

import jax

from repro import configs
from repro.launch import dryrun as DR
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh


def probe(arch, shape, mesh_name="single", overrides=None, dump_buffers=0):
    base = configs.get(arch)
    if overrides:
        typed = {}
        for k, v in overrides.items():
            cur = getattr(base, k)
            typed[k] = type(cur)(v) if cur is not None else v
        cfg = dataclasses.replace(base, **typed)
        configs.get = lambda a, _c=cfg, _o=configs.get: \
            _c if a in (arch,) or a == cfg.name else _o(a)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    fn, args = DR.build_cell(arch, shape, mesh)
    compiled = jax.jit(fn).lower(*args).compile()
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name}
    rec.update(DR.analyze(compiled))
    a = RL.analyze_record(rec)
    t = a["terms"]
    mem = (rec.get("temp_size_in_bytes", 0)
           + rec.get("argument_size_in_bytes", 0)) / 1e9
    print(json.dumps({
        "overrides": overrides or {},
        "compute_s": round(t["compute"], 4), "memory_s": round(t["memory"], 4),
        "collective_s": round(t["collective"], 4),
        "bottleneck": a["bottleneck"],
        "model_hlo_ratio": round(a["useful_ratio"], 3) if a["useful_ratio"] else None,
        "roofline_frac": round(a["roofline_fraction"], 4) if a["roofline_fraction"] else None,
        "mem_GB": round(mem, 1),
        "coll_by_kind_GB": {k: round(v / 1e9, 2)
                            for k, v in (rec.get("collectives") or {}).items()},
    }))
    if dump_buffers:
        import re
        from collections import Counter
        big = Counter()
        for m in re.finditer(r"(f32|bf16|s32|u32|pred)\[([\d,]+)\]",
                             compiled.as_text()):
            dt, dims = m.groups()
            n = 1
            for x in dims.split(","):
                n *= int(x)
            b = n * (4 if dt in ("f32", "s32", "u32") else
                     (1 if dt == "pred" else 2))
            if b > 3e8:
                big[f"{dt}[{dims}]"] = b
        for k, v in sorted(big.items(), key=lambda kv: -kv[1])[:dump_buffers]:
            print(f"  BUF {k}: {v/1e9:.2f} GB")
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--buffers", type=int, default=0)
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.set)
    probe(args.arch, args.shape, args.mesh, overrides, args.buffers)
