"""End-to-end training driver (example application b).

Trains an assigned architecture (reduced or full config) on the synthetic
pipeline with sharded train steps, checkpoint/restart, and loss logging.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 200 --batch 16 --seq 256
"""

from __future__ import annotations

import argparse
import time

import jax

from repro import configs
from repro.dist.checkpoint import CheckpointManager
from repro.launch.mesh import make_local_mesh
from repro.models import sharding as SH
from repro.train import optimizer as OPT
from repro.train.data import DataLoader
from repro.train.train_step import make_train_step, init_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    mesh = make_local_mesh()
    SH.set_mesh_context(mesh, dp_axes=("data",))
    opt = OPT.for_config(cfg, lr=args.lr)
    step_fn = make_train_step(cfg, opt, n_micro=args.micro, mesh=mesh,
                              dp_axes=("data",))
    loader = DataLoader(seed=0, batch=args.batch, seq=args.seq, vocab=cfg.vocab)

    state = init_state(jax.random.PRNGKey(0), cfg, opt)
    start = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr is not None:
        restored = mgr.restore_latest(state)
        if restored is not None:
            state, start, _ = restored
            print(f"[train] resumed from step {start}")

    jstep = jax.jit(step_fn, donate_argnums=0)
    t0 = time.time()
    losses = []
    for it in range(start, args.steps):
        batch = loader(it)
        state, metrics = jstep(state, batch)
        if (it + 1) % args.log_every == 0 or it == start:
            loss = float(metrics["loss"])
            losses.append(loss)
            tok_s = args.batch * args.seq * args.log_every / max(time.time() - t0, 1e-9)
            print(f"[train] step {it + 1} loss {loss:.4f} ({tok_s:,.0f} tok/s)")
            t0 = time.time()
        if mgr is not None and (it + 1) % args.ckpt_every == 0:
            jax.block_until_ready(state["params"])
            mgr.save(it + 1, state)
    if losses:
        print(f"[train] done: first logged loss {losses[0]:.4f} -> "
              f"last {losses[-1]:.4f}")
    else:  # resumed at/after --steps: nothing left to do
        print(f"[train] done: resumed at step {start} >= {args.steps}, no-op")
    return losses


if __name__ == "__main__":
    main()
