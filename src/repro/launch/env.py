"""Environment / launch-profile helper: one place for the process-level JAX
environment knobs the launchers used to set ad hoc via ``os.environ``.

Three idioms (see SNIPPETS.md for their upstream forms):

  * **precision** — :func:`enable_x64` honors the ``JAX_ENABLE_X64``
    environment variable when no explicit flag is given (f64 accumulation
    runs, e.g. ``--backend ref`` with ``dtype='float64'``);
  * **platform** — :func:`set_platform` pins the JAX platform
    (cpu/gpu/tpu) before the backend initializes, and can install the
    documented XLA GPU performance-flag profile (:data:`XLA_GPU_PERF_FLAGS`)
    for future compiled-GPU rows;
  * **host devices** — :func:`set_host_device_count` forces N host CPU
    devices via ``XLA_FLAGS`` (the multi-device tests' idiom) — it MUST run
    before jax first initializes its backends.

Everything importing jax does so lazily inside the function, so this module
can be imported (and ``set_host_device_count`` called) before jax is — the
ordering the distributed test worker needs.
"""

from __future__ import annotations

import os
import re
import warnings

#: The documented GPU launch profile: every XLA flag the compiled-GPU
#: benchmark rows run under, with the rationale each flag is there for.
#: The set follows the published JAX GPU performance guidance (the same
#: profile SNIPPETS.md's upstream launchers install); the mapping is the
#: documentation — ``describe_gpu_profile()`` renders it, and the flag
#: string itself (:data:`XLA_GPU_PERF_FLAGS`) is derived from the keys so
#: the two can never drift apart.
GPU_LAUNCH_PROFILE = {
    "--xla_gpu_enable_triton_softmax_fusion=true":
        "fuse softmax-shaped reductions through Triton instead of cuDNN "
        "calls — keeps the fill's normalize/accumulate epilogues in one "
        "kernel",
    "--xla_gpu_triton_gemm_any=True":
        "let Triton codegen any GEMM (not just flagged ones), so the "
        "one-hot fallbacks lower next to the surrounding fusion rather "
        "than bouncing to cuBLAS",
    "--xla_gpu_enable_async_collectives=true":
        "overlap the sharded fill's cross-device partial-moment reductions "
        "with compute (the C5 chunk contract makes shards independent "
        "until the final sum)",
    "--xla_gpu_enable_latency_hiding_scheduler=true":
        "schedule HBM loads/collectives ahead of their consumers — the "
        "fill is bandwidth-bound between kernel launches",
    "--xla_gpu_enable_highest_priority_async_stream=true":
        "give the async-collective stream top priority so a small "
        "all-reduce never waits behind a long fill kernel",
}

#: Space-joined form of :data:`GPU_LAUNCH_PROFILE` for ``XLA_FLAGS``.
#: Harmless on CPU/TPU (unknown flags are rejected loudly by XLA only when
#: a GPU backend consumes them), but only installed on request
#: (``set_platform(..., gpu_flags=True)`` or ``--gpu-flags``).
XLA_GPU_PERF_FLAGS = " ".join(GPU_LAUNCH_PROFILE)


def describe_gpu_profile() -> str:
    """Human-readable flag -> rationale table (``--gpu-flags`` + ``--plan``
    and README's GPU quickstart render this)."""
    return "\n".join(f"{flag}\n    {why}"
                     for flag, why in GPU_LAUNCH_PROFILE.items())

_TRUTHY = ("1", "true", "yes", "on")


def _jax_initialized() -> bool:
    """True once jax has committed to its backends (after which platform /
    device-count changes are silently ineffective)."""
    import sys
    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        return jax._src.xla_bridge._backends != {}  # noqa: SLF001
    except Exception:
        return False


def enable_x64(enable: bool | None = None) -> bool:
    """Enable (or disable) 64-bit JAX types.  ``None`` reads the standard
    ``JAX_ENABLE_X64`` environment variable (unset -> False).  Safe to call
    after jax import; returns the value applied."""
    if enable is None:
        enable = os.environ.get("JAX_ENABLE_X64", "").lower() in _TRUTHY
    import jax
    jax.config.update("jax_enable_x64", bool(enable))
    return bool(enable)


def set_platform(platform: str | None = None, *,
                 gpu_flags: bool = False) -> str | None:
    """Pin the JAX platform (``'cpu'``/``'gpu'``/``'tpu'``).  ``None``
    reads ``JAX_PLATFORMS`` / ``JAX_PLATFORM_NAME`` and applies nothing if
    both are unset.  ``gpu_flags=True`` additionally installs
    :data:`XLA_GPU_PERF_FLAGS` into ``XLA_FLAGS`` (before backend init
    only).  Returns the platform applied, or None."""
    if platform is None:
        platform = (os.environ.get("JAX_PLATFORMS")
                    or os.environ.get("JAX_PLATFORM_NAME"))
        if not platform:
            return None
    if gpu_flags:
        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_gpu_enable_triton_softmax_fusion" not in flags:
            if _jax_initialized():
                warnings.warn("XLA GPU flags set after jax initialized its "
                              "backends — they will not take effect",
                              RuntimeWarning, stacklevel=2)
            os.environ["XLA_FLAGS"] = f"{flags} {XLA_GPU_PERF_FLAGS}".strip()
    if _jax_initialized():
        warnings.warn(f"set_platform({platform!r}) after jax initialized "
                      f"its backends — the platform cannot change anymore",
                      RuntimeWarning, stacklevel=2)
        return platform
    import jax
    try:
        jax.config.update("jax_platforms", platform)
    except (AttributeError, ValueError):   # older spelling
        jax.config.update("jax_platform_name", platform)
    return platform


def set_host_device_count(n: int) -> int:
    """Force ``n`` host CPU devices via
    ``--xla_force_host_platform_device_count`` (the multi-device test /
    example idiom).  Must run BEFORE jax initializes its backends; replaces
    any prior count in ``XLA_FLAGS`` instead of appending duplicates."""
    if _jax_initialized():
        warnings.warn(f"set_host_device_count({n}) after jax initialized "
                      f"its backends — the device count cannot change "
                      f"anymore", RuntimeWarning, stacklevel=2)
    flags = os.environ.get("XLA_FLAGS", "")
    flags = re.sub(r"--xla_force_host_platform_device_count=\S+", "",
                   flags).strip()
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip())
    return n


def add_env_args(ap) -> None:
    """The shared environment flags (integrate / sweep / serve CLIs)."""
    ap.add_argument("--x64", action="store_true",
                    help="enable 64-bit JAX types (also honored from "
                         "JAX_ENABLE_X64=1)")
    ap.add_argument("--platform", choices=["cpu", "gpu", "tpu"],
                    default=None,
                    help="pin the JAX platform (must act before the first "
                         "computation; default: JAX_PLATFORMS/autodetect)")
    ap.add_argument("--gpu-flags", action="store_true",
                    help="install the documented XLA GPU performance flag "
                         "profile (launch.env.XLA_GPU_PERF_FLAGS)")
    ap.add_argument("--host-devices", type=int, default=None, metavar="N",
                    help="force N host CPU devices (XLA_FLAGS; must act "
                         "before jax backend init)")


def apply_env_args(args) -> None:
    """Apply the `add_env_args` flags in dependency order: device count and
    platform first (backend-init-sensitive), x64 last (always safe)."""
    if getattr(args, "host_devices", None):
        set_host_device_count(args.host_devices)
    if getattr(args, "platform", None) or getattr(args, "gpu_flags", False):
        set_platform(args.platform, gpu_flags=args.gpu_flags)
    if getattr(args, "x64", False) or "JAX_ENABLE_X64" in os.environ:
        enable_x64(True if getattr(args, "x64", False) else None)
