"""Asian option pricing (paper §4.5.1): price a 16-step arithmetic-average
Asian call by VEGAS+ integration over the uniform hypercube, and validate the
machinery against the geometric-average variant's closed form.

  PYTHONPATH=src python examples/asian_option.py
"""

import time

import jax

from repro.core import VegasConfig, run
from repro.core.integrands import make_asian_option

cfg = VegasConfig(neval=400_000, max_it=15, skip=5, ninc=512)

# 1) geometric average: exact closed form exists -> validation
geo = make_asian_option(geometric=True)
t0 = time.time()
r = run(geo, cfg, key=jax.random.PRNGKey(0))
print(f"geometric Asian call : {r.mean:.6f} +- {r.sdev:.2g}  "
      f"(closed form {geo.target:.6f}, pull {(r.mean - geo.target)/r.sdev:+.2f}, "
      f"{time.time()-t0:.1f}s)")

# 2) arithmetic average: no closed form; this is the paper's benchmark
arith = make_asian_option(geometric=False)
t0 = time.time()
r = run(arith, cfg, key=jax.random.PRNGKey(0))
print(f"arithmetic Asian call: {r.mean:.6f} +- {r.sdev:.2g}  "
      f"(chi2/dof {r.chi2_dof:.2f}, {time.time()-t0:.1f}s)")
print("(arithmetic > geometric by AM-GM, as expected:",
      bool(r.mean > geo.target), ")")
