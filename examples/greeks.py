"""Greeks from the differentiable engine: one vjp per scenario (§11).

  PYTHONPATH=src python examples/greeks.py

Prices a geometric Asian call at 5 (strike, sigma) scenarios AND
differentiates each price w.r.t. both contract parameters — the dual delta
``d(price)/d(strike)`` and the vega ``d(price)/d(sigma)`` — in one vmapped
two-phase program: adapt with gradients stopped, then a frozen-map
evaluation pass whose pathwise Monte Carlo gradient is exact.  Each
gradient comes with its own error bar (the derivative integrand is itself
a VEGAS integral), checked here against finite differences of the exact
closed-form price curve.
"""

import time

import jax
import numpy as np

from repro.batch.family import make_asian_greeks_family
from repro.core import VegasConfig
from repro.core.targets import asian_geometric_closed_form as exact_price
from repro.engine import ExecutionConfig, GradPolicy, execute, make_plan

strikes = np.linspace(90.0, 110.0, 5)
sigmas = np.full(5, 0.2)
family = make_asian_greeks_family(strikes, sigmas, n_steps=8)
cfg = VegasConfig(neval=50_000, max_it=10, ninc=128,
                  execution=ExecutionConfig(grad=GradPolicy()))

plan = make_plan(family, cfg)
print(plan.describe(), "\n")

t0 = time.perf_counter()
res = execute(plan, key=jax.random.PRNGKey(0))
print(f"grad sweep: {time.perf_counter() - t0:.2f}s "
      f"(B={res.batch_size}, mode={res.mode})\n")

kw = dict(s0=100.0, r=0.1, t_mat=1.0, n=8)
print("  K     price (MC +- sd)      dP/dK (MC +- sd)   exact-FD   "
      "dP/dsig (MC +- sd)  exact-FD")
for b, (k, sig) in enumerate(zip(strikes, sigmas)):
    # Finite differences of the CLOSED FORM — an exact yardstick, no MC.
    fd_k = (exact_price(strike=k + 0.5, sigma=sig, **kw)
            - exact_price(strike=k - 0.5, sigma=sig, **kw))
    fd_s = (exact_price(strike=k, sigma=sig + 5e-3, **kw)
            - exact_price(strike=k, sigma=sig - 5e-3, **kw)) / 1e-2
    print(f"  {k:5.1f} {res.mean[b]:8.4f} +- {res.sdev[b]:.2g}   "
          f"{res.grad['strike'][b]:+8.4f} +- {res.grad_sdev['strike'][b]:.2g}"
          f"  {fd_k:+8.4f}  "
          f"{res.grad['sigma'][b]:+8.3f} +- {res.grad_sdev['sigma'][b]:.2g}"
          f"  {fd_s:+8.3f}")
