"""End-to-end training example: a ~100M-class model (smollm-135m family,
reduced width for CPU) for a few hundred steps on the synthetic pipeline,
with checkpointing.

  PYTHONPATH=src python examples/train_smollm.py
"""

from repro.launch.train import main

losses = main(["--arch", "smollm-135m", "--reduced", "--steps", "200",
               "--batch", "8", "--seq", "256", "--log-every", "25",
               "--ckpt-dir", "/tmp/repro_smollm_ckpt", "--ckpt-every", "100"])
assert losses[-1] < losses[0], "training must reduce the loss"
print("OK: loss went down.")
