"""Quickstart: integrate a peaked 4D Gaussian with VEGAS+ in ~10 lines.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import Integrand, VegasConfig, run


# any batched jax function works; bounds + dim come with the Integrand
def f(x):  # sharp Gaussian bump at the center of [0,1]^4
    return jnp.exp(-jnp.sum((x - 0.5) ** 2, axis=-1) / (2 * 0.02**2))


integrand = Integrand("bump", dim=4, fn=f, lower=(0.0,) * 4, upper=(1.0,) * 4)

result = run(integrand,
             VegasConfig(neval=200_000, max_it=15, skip=5, ninc=512),
             key=jax.random.PRNGKey(0))

exact = (0.02 * (2 * 3.141592653589793) ** 0.5) ** 4  # untruncated Gaussian
print(result)
print(f"exact (untruncated): {exact:.8g}")
print(f"pull: {(result.mean - exact) / result.sdev:+.2f} sigma")
