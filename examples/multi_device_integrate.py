"""Multi-device VEGAS+ (paper §3.4/§4.4 on a JAX mesh): shard the fill over
all local devices via shard_map, with checkpoint + elastic resume.

Run with forced host devices to see the multi-device path on CPU:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/multi_device_integrate.py
"""

import tempfile
import time

import jax

from repro.core import VegasConfig, run
from repro.core.integrands import make_ridge
from repro.dist.checkpoint import CheckpointManager
from repro.dist.sharded_fill import make_sharded_fill
from repro.launch.mesh import make_local_mesh

print(f"devices: {jax.device_count()}")
mesh = make_local_mesh()

ig = make_ridge(dim=4, n_peaks=100)
cfg = VegasConfig(neval=200_000, max_it=12, skip=4, ninc=512)
rc = cfg.resolve(ig.dim)
fill = make_sharded_fill(mesh, ("data",), rc)

with tempfile.TemporaryDirectory() as td:
    mgr = CheckpointManager(td)
    t0 = time.time()
    r = run(ig, cfg, key=jax.random.PRNGKey(0), fill_fn=fill,
            checkpoint_cb=lambda it, s: mgr.save(it, s))
    print(f"sharded result: {r}")
    print(f"target {ig.target:.6g}, pull {(r.mean - ig.target)/r.sdev:+.2f}, "
          f"{time.time()-t0:.1f}s")

    # elastic resume demo: restore the 12-iteration state, run 4 more
    restored, step, _ = mgr.restore_latest(r.state)
    cfg2 = VegasConfig(neval=200_000, max_it=16, skip=4, ninc=512)
    r2 = run(ig, cfg2, key=jax.random.PRNGKey(0), state=restored, fill_fn=fill)
    print(f"resumed +4 iterations: {r2}")
