"""Multi-device VEGAS+ (paper §3.4/§4.4 on a JAX mesh): the execution engine
composes the sharded fill (shard_map over all local devices) with a
checkpoint policy — one ExecutionConfig instead of hand-wired fill_fn +
callback plumbing (DESIGN.md §9).

Run with forced host devices to see the multi-device path on CPU:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/multi_device_integrate.py
"""

import tempfile
import time

import jax

from repro.core import VegasConfig, run
from repro.core.integrands import make_ridge
from repro.dist.checkpoint import CheckpointManager
from repro.engine import CheckpointPolicy, ExecutionConfig, make_plan
from repro.launch.mesh import make_local_mesh

print(f"devices: {jax.device_count()}")
mesh = make_local_mesh()

ig = make_ridge(dim=4, n_peaks=100)

with tempfile.TemporaryDirectory() as td:
    execution = ExecutionConfig(mesh=mesh,
                                checkpoint=CheckpointPolicy(directory=td))
    cfg = VegasConfig(neval=200_000, max_it=12, skip=4, ninc=512,
                      execution=execution)
    print(make_plan(ig, cfg).describe())
    t0 = time.time()
    r = run(ig, cfg, key=jax.random.PRNGKey(0))
    print(f"sharded result: {r}")
    print(f"target {ig.target:.6g}, pull {(r.mean - ig.target)/r.sdev:+.2f}, "
          f"{time.time()-t0:.1f}s")

    # elastic resume demo: restore the 12-iteration state, run 4 more
    restored, step, _ = CheckpointManager(td).restore_latest(r.state)
    cfg2 = VegasConfig(neval=200_000, max_it=16, skip=4, ninc=512,
                       execution=execution)
    r2 = run(ig, cfg2, key=jax.random.PRNGKey(0), state=restored)
    print(f"resumed +4 iterations: {r2}")
