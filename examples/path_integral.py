"""Feynman path integral (paper §4.6): lattice propagator of the 1D harmonic
oscillator <x|e^{-HT}|x> at x=0, compared against (a) the exact value of the
lattice (Gaussian) integral and (b) the continuum propagator.

  PYTHONPATH=src python examples/path_integral.py
"""

import time

import jax

from repro.core import VegasConfig, run
from repro.core.integrands import make_feynman_path
from repro.core.targets import harmonic_propagator_exact

ig = make_feynman_path(n_slices=9, t_total=4.0)  # 8-dimensional integral
cfg = VegasConfig(neval=400_000, max_it=15, skip=5, ninc=512)

t0 = time.time()
r = run(ig, cfg, key=jax.random.PRNGKey(0))
print(f"VEGAS+ lattice estimate : {r.mean:.8g} +- {r.sdev:.2g} "
      f"({time.time()-t0:.1f}s, chi2/dof {r.chi2_dof:.2f})")
print(f"lattice exact (Gaussian): {ig.target:.8g}   "
      f"pull {(r.mean - ig.target)/r.sdev:+.2f} sigma")
print(f"continuum propagator    : {harmonic_propagator_exact(0.0, 4.0):.8g} "
      f"(differs by O(a^2) discretization)")
