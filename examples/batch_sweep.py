"""Batched sweep: price an Asian option at 8 strikes in ONE jitted program.

  PYTHONPATH=src python examples/batch_sweep.py

Every strike is a scenario on the batch axis: all 8 adapt their importance
maps and integrate concurrently (repro.batch, DESIGN.md §6).  A MapCache
warm-starts the maps on the second sweep — the serving-style amortization
for repeated sweeps over the same family.
"""

import time

import jax
import numpy as np

from repro.batch import MapCache, run_batch
from repro.batch.family import make_asian_family
from repro.core import VegasConfig

family = make_asian_family(np.linspace(85.0, 115.0, 8), n_steps=8,
                           geometric=True)
cfg = VegasConfig(neval=50_000, max_it=10, skip=4, ninc=128)
cache = MapCache()

t0 = time.perf_counter()
res = run_batch(family, cfg, key=jax.random.PRNGKey(0), cache=cache)
print(f"cold sweep: {time.perf_counter() - t0:.2f}s")
for b in range(res.batch_size):
    strike = float(np.asarray(family.params)[b])
    pull = (res.mean[b] - family.targets[b]) / res.sdev[b]
    print(f"  K={strike:6.1f}  price={res.mean[b]:.5f} +- {res.sdev[b]:.2g}"
          f"  closed-form={family.targets[b]:.5f}  pull={pull:+.2f}")

t0 = time.perf_counter()
res2 = run_batch(family, cfg, key=jax.random.PRNGKey(1), cache=cache)
print(f"warm sweep: {time.perf_counter() - t0:.2f}s "
      f"(warm_started={res2.warm_started})")
